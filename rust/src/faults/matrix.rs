//! The scenario × policy fault matrix: run every built-in fault
//! scenario under every policy on one oversubscribed row and score
//! containment. This is the grid behind `polca faults matrix` and the
//! `fault-matrix` experiment id.
//!
//! Invariants the grid itself checks (the ISSUE-3 acceptance shape):
//! the "none" column is produced by injecting an *empty* plan and must
//! match a run with no plan at all bit-for-bit ([`MatrixOutcome::clean_match`]),
//! and every injected-fault cell reports a finite time-to-contain under
//! at least one policy ([`MatrixOutcome::scenarios_containable`]).

use crate::exec::{run_batch, ExecConfig};
use crate::metrics::{ResilienceMetrics, RunReport};
use crate::policy::engine::PolicyKind;
use crate::scenario::Scenario;
use crate::simulation::{run, SimConfig};
use crate::util::csv::Csv;
use crate::util::json::Json;
use crate::util::table::{f, Table};

use super::plan::FaultPlan;

/// Matrix parameters: one row configuration shared by every cell.
#[derive(Debug, Clone)]
pub struct MatrixConfig {
    /// Scenario names (see [`FaultPlan::scenario_names`]).
    pub scenarios: Vec<String>,
    /// Policies to grid against (columns).
    pub policies: Vec<PolicyKind>,
    /// Baseline (budget) server count of the row.
    pub servers: usize,
    /// Added-server fraction (oversubscription) — faults should hit a
    /// row that actually exercises the control loop.
    pub added: f64,
    /// Simulated horizon, weeks.
    pub weeks: f64,
    /// Seed (shared across cells: one workload realization).
    pub seed: u64,
    /// Containment escalation passed to every cell (including the
    /// no-fault column, so the comparison is policy-for-policy fair).
    pub escalation_s: Option<f64>,
    /// Fan the grid's cells out across the parallel scenario executor
    /// (false = the serial reference path; bit-identical either way,
    /// every cell is a pure function of its config).
    pub parallel: bool,
}

impl Default for MatrixConfig {
    fn default() -> Self {
        MatrixConfig {
            scenarios: FaultPlan::scenario_names().iter().map(|s| s.to_string()).collect(),
            policies: PolicyKind::all().to_vec(),
            servers: 16,
            added: 0.30,
            weeks: 0.1,
            seed: 1,
            escalation_s: Some(120.0),
            parallel: true,
        }
    }
}

impl MatrixConfig {
    /// The simulated horizon in seconds (scenario windows scale to it).
    pub fn horizon_s(&self) -> f64 {
        self.weeks * 7.0 * 86_400.0
    }

    /// The declarative [`Scenario`] for one (plan, policy) cell — the
    /// grid is an enumeration of scenario values.
    pub fn scenario(&self, plan: Option<FaultPlan>, policy: PolicyKind) -> Scenario {
        let mut b = Scenario::builder("fault-cell")
            .policy(policy)
            .weeks(self.weeks)
            .seed(self.seed)
            .servers(self.servers)
            .added(self.added);
        if let Some(esc) = self.escalation_s {
            b = b.escalate(esc);
        }
        if let Some(p) = plan {
            b = b.faults(p);
        }
        b.build()
    }

    /// The cell configuration for one (plan, policy) pair (derived from
    /// [`MatrixConfig::scenario`]).
    pub fn sim_config(&self, plan: Option<FaultPlan>, policy: PolicyKind) -> SimConfig {
        self.scenario(plan, policy).sim_config()
    }
}

/// One cell of the grid: containment observables for a scenario run
/// under one policy.
#[derive(Debug, Clone)]
pub struct MatrixCell {
    /// Scenario name ("none" = the empty-plan control column).
    pub scenario: String,
    /// Policy the cell ran under.
    pub policy: PolicyKind,
    /// Peak of the *reported* readings (meter bias corrupts this).
    pub reported_peak: f64,
    /// Peak of true power over effective budget (the ground truth).
    pub true_peak: f64,
    /// Seconds the row spent over its effective budget.
    pub violation_s: f64,
    /// Largest instantaneous excess over the effective budget, watts.
    pub peak_overshoot_w: f64,
    /// Worst incident time-to-contain ([`f64::INFINITY`] = never).
    pub time_to_contain_s: f64,
    /// Whether every injected incident was contained before the horizon.
    pub contained: bool,
    /// Policy brake decisions.
    pub brake_events: u64,
    /// Fast-path brake deliveries.
    pub brake_commands: u64,
    /// Slow-path cap commands that took effect.
    pub cap_commands: u64,
    /// Slow-path commands re-issued after an apply timeout.
    pub reissued_commands: u64,
}

impl MatrixCell {
    fn from_report(scenario: &str, policy: PolicyKind, report: &RunReport) -> MatrixCell {
        let r = &report.resilience;
        MatrixCell {
            scenario: scenario.to_string(),
            policy,
            reported_peak: report.power_peak,
            true_peak: r.true_peak_norm,
            violation_s: r.violation_s,
            peak_overshoot_w: r.peak_overshoot_w,
            time_to_contain_s: r.worst_time_to_contain_s(),
            contained: r.all_contained(),
            brake_events: report.brake_events,
            brake_commands: report.brake_commands,
            cap_commands: report.cap_commands,
            reissued_commands: r.reissued_commands,
        }
    }
}

/// The full grid plus the cross-cell verdicts.
#[derive(Debug, Clone)]
pub struct MatrixOutcome {
    /// Cells in scenario-major, policy-minor order.
    pub cells: Vec<MatrixCell>,
    /// Whether every policy's "none" column matched its no-plan clean
    /// run exactly (events, completions, commands, power statistics).
    pub clean_match: bool,
    /// The horizon the scenario windows were scaled to, seconds.
    pub horizon_s: f64,
}

impl MatrixOutcome {
    /// Cells of one scenario, in policy order.
    pub fn row(&self, scenario: &str) -> Vec<&MatrixCell> {
        self.cells.iter().filter(|c| c.scenario == scenario).collect()
    }

    /// Scenario names present in the grid, in insertion order.
    pub fn scenarios(&self) -> Vec<&str> {
        let mut seen = Vec::new();
        for c in &self.cells {
            if !seen.contains(&c.scenario.as_str()) {
                seen.push(c.scenario.as_str());
            }
        }
        seen
    }

    /// Whether every injected-fault scenario has at least one policy
    /// that contains it (finite worst time-to-contain). The "none"
    /// column is trivially contained and excluded.
    pub fn scenarios_containable(&self) -> bool {
        self.scenarios()
            .iter()
            .filter(|s| **s != "none")
            .all(|s| self.row(s).iter().any(|c| c.contained))
    }

    /// Render the grid as a table (shared by the CLI and the
    /// `fault-matrix` experiment).
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Fault matrix: scenario × policy containment",
            &[
                "scenario", "policy", "reported peak", "true peak", "viol s", "overshoot W",
                "ttc", "brakes", "caps", "reissued",
            ],
        );
        for c in &self.cells {
            t.row(vec![
                c.scenario.clone(),
                c.policy.name().to_string(),
                f(c.reported_peak, 3),
                f(c.true_peak, 3),
                f(c.violation_s, 1),
                f(c.peak_overshoot_w, 0),
                ResilienceMetrics::fmt_ttc(c.time_to_contain_s),
                c.brake_events.to_string(),
                c.cap_commands.to_string(),
                c.reissued_commands.to_string(),
            ]);
        }
        t
    }

    /// The grid as CSV (one row per cell).
    pub fn csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "scenario", "policy", "reported_peak", "true_peak", "violation_s",
            "peak_overshoot_w", "time_to_contain_s", "contained", "brake_events",
            "brake_commands", "cap_commands", "reissued_commands",
        ]);
        for c in &self.cells {
            csv.row_strs(&[
                c.scenario.clone(),
                c.policy.name().to_string(),
                f(c.reported_peak, 4),
                f(c.true_peak, 4),
                f(c.violation_s, 2),
                f(c.peak_overshoot_w, 1),
                if c.time_to_contain_s.is_infinite() {
                    "inf".to_string()
                } else {
                    f(c.time_to_contain_s, 2)
                },
                (c.contained as u8).to_string(),
                c.brake_events.to_string(),
                c.brake_commands.to_string(),
                c.cap_commands.to_string(),
                c.reissued_commands.to_string(),
            ]);
        }
        csv
    }

    /// The grid as machine-readable JSON (`polca faults matrix --json`):
    /// one object per cell plus the cross-cell verdicts, so scripts can
    /// consume containment results without scraping the table.
    pub fn to_json(&self) -> Json {
        let cells = self.cells.iter().map(|c| {
            Json::obj(vec![
                ("scenario", Json::Str(c.scenario.clone())),
                ("policy", Json::Str(c.policy.name().to_string())),
                ("reported_peak", Json::Num(c.reported_peak)),
                ("true_peak", Json::Num(c.true_peak)),
                ("violation_s", Json::Num(c.violation_s)),
                ("peak_overshoot_w", Json::Num(c.peak_overshoot_w)),
                // Json::num: "never contained" is null, not a fake
                // large number (the crate-wide non-finite convention).
                ("time_to_contain_s", Json::num(c.time_to_contain_s)),
                ("contained", Json::Bool(c.contained)),
                ("brake_events", Json::Num(c.brake_events as f64)),
                ("brake_commands", Json::Num(c.brake_commands as f64)),
                ("cap_commands", Json::Num(c.cap_commands as f64)),
                ("reissued_commands", Json::Num(c.reissued_commands as f64)),
            ])
        });
        Json::obj(vec![
            ("horizon_s", Json::Num(self.horizon_s)),
            ("clean_match", Json::Bool(self.clean_match)),
            ("scenarios_containable", Json::Bool(self.scenarios_containable())),
            ("cells", Json::arr(cells)),
        ])
    }
}

/// Two runs agree on everything a fault could have perturbed.
fn reports_match(a: &RunReport, b: &RunReport) -> bool {
    a.events == b.events
        && a.hp.completed == b.hp.completed
        && a.lp.completed == b.lp.completed
        && a.hp.dropped == b.hp.dropped
        && a.lp.dropped == b.lp.dropped
        && a.brake_events == b.brake_events
        && a.cap_commands == b.cap_commands
        && a.uncap_commands == b.uncap_commands
        && a.brake_commands == b.brake_commands
        && a.power_peak == b.power_peak
        && a.power_mean == b.power_mean
        && a.spike_2s == b.spike_2s
        && a.resilience.violation_s == b.resilience.violation_s
        && a.resilience.reissued_commands == b.resilience.reissued_commands
}

/// Run the grid: every scenario under every policy, plus one no-plan
/// clean run per policy to certify the "none" column. The cell configs
/// are resolved up front (a bad scenario name fails before anything
/// runs), then the whole batch — clean references included — fans out
/// through the parallel scenario executor ([`crate::exec`]); results
/// are bit-identical to the serial path, so `parallel` only buys
/// wall-clock.
pub fn run_matrix(mc: &MatrixConfig) -> anyhow::Result<MatrixOutcome> {
    let horizon_s = mc.horizon_s();
    let n_policies = mc.policies.len();
    // One clean (no-plan) reference per policy, then the grid in
    // scenario-major, policy-minor order.
    let mut jobs: Vec<SimConfig> = Vec::with_capacity((mc.scenarios.len() + 1) * n_policies);
    for &p in &mc.policies {
        jobs.push(mc.sim_config(None, p));
    }
    for scenario in &mc.scenarios {
        let plan = FaultPlan::scenario(scenario, horizon_s)?;
        for &policy in &mc.policies {
            jobs.push(mc.sim_config(Some(plan.clone()), policy));
        }
    }
    let reports = run_batch(&jobs, &ExecConfig::with_parallel(mc.parallel), |_, cfg| run(cfg));
    let (cleans, grid) = reports.split_at(n_policies);

    let mut cells = Vec::with_capacity(mc.scenarios.len() * n_policies);
    let mut clean_match = true;
    for (si, scenario) in mc.scenarios.iter().enumerate() {
        for (pi, &policy) in mc.policies.iter().enumerate() {
            let report = &grid[si * n_policies + pi];
            if scenario == "none" {
                clean_match &= reports_match(report, &cleans[pi]);
            }
            cells.push(MatrixCell::from_report(scenario, policy, report));
        }
    }
    Ok(MatrixOutcome { cells, clean_match, horizon_s })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A small grid exercising the two acceptance invariants: the
    /// no-fault column is bit-identical to the clean run, and every
    /// fault scenario is containable under at least one policy.
    #[test]
    fn quick_matrix_holds_the_acceptance_invariants() {
        let mc = MatrixConfig {
            scenarios: vec![
                "none".into(),
                "cap-ignore".into(),
                "feed-loss".into(),
            ],
            policies: vec![PolicyKind::Polca, PolicyKind::NoCap],
            servers: 12,
            added: 0.5,
            weeks: 0.05,
            seed: 3,
            escalation_s: Some(120.0),
            parallel: true,
        };
        let out = run_matrix(&mc).unwrap();
        assert_eq!(out.cells.len(), 6);
        assert!(out.clean_match, "the none column must match the clean run");
        assert!(out.scenarios_containable(), "{:#?}", out.cells);
        // The none column reports no incidents at all.
        for c in out.row("none") {
            assert!(c.contained);
            assert_eq!(c.time_to_contain_s, 0.0);
        }
        // Rendering covers every cell.
        assert!(out.table().render().contains("cap-ignore"));
    }

    #[test]
    fn parallel_grid_is_bit_identical_to_serial() {
        let mut mc = MatrixConfig {
            scenarios: vec!["none".into(), "meter-bias".into()],
            policies: vec![PolicyKind::Polca, PolicyKind::NoCap],
            servers: 12,
            added: 0.4,
            weeks: 0.03,
            seed: 5,
            escalation_s: Some(120.0),
            parallel: true,
        };
        let par = run_matrix(&mc).unwrap();
        mc.parallel = false;
        let ser = run_matrix(&mc).unwrap();
        assert_eq!(format!("{par:?}"), format!("{ser:?}"));
    }

    #[test]
    fn json_output_covers_every_cell_and_verdict() {
        let mc = MatrixConfig {
            scenarios: vec!["none".into()],
            policies: vec![PolicyKind::NoCap],
            servers: 12,
            added: 0.2,
            weeks: 0.02,
            seed: 2,
            escalation_s: None,
            parallel: true,
        };
        let out = run_matrix(&mc).unwrap();
        let j = out.to_json();
        assert_eq!(j.get("clean_match").and_then(|v| v.as_bool()), Some(true));
        let cells = j.get("cells").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].get("scenario").and_then(|v| v.as_str()), Some("none"));
        // ... and the rendered document parses back.
        let parsed = crate::util::json::parse(&j.to_pretty()).unwrap();
        assert_eq!(parsed.get("clean_match"), j.get("clean_match"));
    }

    #[test]
    fn unknown_scenario_errors() {
        let mc = MatrixConfig {
            scenarios: vec!["bogus".into()],
            policies: vec![PolicyKind::NoCap],
            weeks: 0.01,
            ..Default::default()
        };
        assert!(run_matrix(&mc).is_err());
    }
}
