//! Fault injection & resilience: prove POLCA stays safe when the
//! telemetry and controls misbehave.
//!
//! The paper's engineering claim is not the headroom number but that
//! oversubscription can be made *robust and reliable* despite the
//! "stringent set of telemetry and controls that GPUs offer in a
//! virtualized environment" (§6–§7). The rest of this crate simulates a
//! well-behaved control plane; this module is the adversary:
//!
//! * [`FaultPlan`] / [`FaultKind`] — a deterministic, seedable timeline
//!   of fault episodes spanning the whole control loop: telemetry
//!   dropouts, OOB loss bursts and latency storms, cap-ignoring
//!   servers, meter miscalibration, and feed-loss budget cuts.
//! * [`matrix`] — the scenario × policy containment grid
//!   (`polca faults matrix`, experiment id `fault-matrix`).
//! * Scoring lives in [`crate::metrics::ResilienceMetrics`]: ground-truth
//!   budget-violation seconds, peak overshoot watts, and per-incident
//!   time-to-contain — settled exactly on every power change, so a
//!   lying meter cannot hide a violation from the scoreboard.
//! * The planner's fault-mode answer is
//!   [`crate::fleet::planner::plan_site_under_faults`]: the *derated*
//!   oversubscription level that stays within a containment SLO even
//!   while the fault plan replays, printed next to the clean number.
//!
//! The runbook mapping each fault kind to the paper passage motivating
//! it, the knob that injects it, the metric that detects it, and the
//! expected policy response is `docs/RELIABILITY.md`.

pub mod matrix;
pub mod plan;

pub use matrix::{run_matrix, MatrixCell, MatrixConfig, MatrixOutcome};
pub use plan::{FaultEvent, FaultKind, FaultPlan};

/// Containment SLO for fault-mode planning: how much budget violation a
/// site operator tolerates while a fault plan replays (the knob behind
/// [`crate::fleet::planner::plan_site_under_faults`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ContainmentSlo {
    /// Max total seconds over the effective budget, per cluster run.
    pub max_violation_s: f64,
    /// Max per-incident time-to-contain, seconds (infinite = never
    /// contained, which always fails).
    pub max_time_to_contain_s: f64,
    /// Max instantaneous overshoot as a fraction of the cluster budget
    /// (the UPS tolerates 133% for 10 s, §4.E — stay well under it).
    pub max_overshoot_frac: f64,
}

impl Default for ContainmentSlo {
    fn default() -> Self {
        ContainmentSlo {
            max_violation_s: 60.0,
            max_time_to_contain_s: 120.0,
            max_overshoot_frac: 0.25,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn containment_slo_defaults_are_sane() {
        let slo = ContainmentSlo::default();
        assert!(slo.max_violation_s > 0.0);
        assert!(slo.max_time_to_contain_s >= slo.max_violation_s);
        // Stay under the §4.E UPS tolerance band (133% for 10 s).
        assert!(slo.max_overshoot_frac < 0.33);
    }
}
