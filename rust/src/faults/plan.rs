//! Deterministic fault timelines: what breaks, when, and for how long.
//!
//! A [`FaultPlan`] is a seedable, fully deterministic list of
//! [`FaultEvent`] episodes that the row simulator replays alongside the
//! workload (see [`crate::simulation`]). Each episode degrades one link
//! of the paper's control loop — the telemetry the power manager reads,
//! the OOB channel it actuates through, the servers that are supposed
//! to obey, the meter calibration, or the electrical budget itself —
//! so a policy can be *falsified* (shown to lose containment) rather
//! than merely scored on a well-behaved control plane.
//!
//! The same plan injected into the same seeded simulation yields a
//! bit-identical run; an empty plan is bit-identical to not injecting
//! at all (property-tested in `tests/integration_faults.rs`).

use crate::util::rng::Rng;

/// One way the control plane can misbehave (docs/RELIABILITY.md maps
/// each kind to the paper passage motivating it and the expected
/// policy response).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Telemetry dropout: the PDU pipeline stalls and the power manager
    /// keeps reading the last sample that was visible when the episode
    /// began ([`crate::cluster::telemetry::TelemetryBuffer::freeze`]).
    /// The meter itself keeps measuring — only visibility degrades.
    TelemetryFreeze,
    /// OOB command-loss burst and latency storm on the slow (SMBPBI via
    /// BMC) path. The brake path is a dedicated hardware signal and is
    /// unaffected (§4: "extremely reliable").
    OobStorm {
        /// Probability a slow-path command is silently lost.
        loss_prob: f64,
        /// Multiplier on the slow-path apply latency (storm congestion).
        latency_mult: f64,
        /// Latency jitter fraction (uniform ±) during the storm.
        jitter_frac: f64,
    },
    /// Cap-ignore servers: a fraction of the row acknowledges frequency
    /// commands but does not apply them (wedged GPU driver / BMC
    /// firmware). Because the commands *are* acknowledged, re-issuing
    /// cannot repair this — only the brake path contains it.
    CapIgnore {
        /// Fraction of deployed servers that ignore cap/uncap commands
        /// (the first `ceil(frac · n)` slots of the row, deterministic).
        server_frac: f64,
    },
    /// Meter miscalibration: reported power is `mult ×` the true draw.
    /// `mult < 1` makes the policy under-react (the dangerous case).
    MeterBias {
        /// Multiplicative bias on every reported reading.
        mult: f64,
    },
    /// Feed loss: a redundancy event cuts the effective power budget to
    /// `budget_frac ×` nominal for the duration ("From Servers to
    /// Sites": site planning must survive redundancy events). The power
    /// manager is informed — its normalized reading jumps accordingly.
    FeedLoss {
        /// Remaining fraction of the nominal budget during the episode.
        budget_frac: f64,
    },
}

impl FaultKind {
    /// Stable label used in reports, CSVs and scenario names.
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::TelemetryFreeze => "telemetry-freeze",
            FaultKind::OobStorm { .. } => "oob-storm",
            FaultKind::CapIgnore { .. } => "cap-ignore",
            FaultKind::MeterBias { .. } => "meter-bias",
            FaultKind::FeedLoss { .. } => "feed-loss",
        }
    }
}

/// One scheduled fault episode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// What breaks.
    pub kind: FaultKind,
    /// When the episode begins, seconds into the run.
    pub start_s: f64,
    /// Episode length, seconds.
    pub duration_s: f64,
}

impl FaultEvent {
    /// When the episode ends (state is restored), seconds into the run.
    pub fn end_s(&self) -> f64 {
        self.start_s + self.duration_s
    }
}

/// A deterministic timeline of fault episodes injected into one run.
///
/// ```
/// use polca::faults::{FaultKind, FaultPlan};
///
/// let plan = FaultPlan::new()
///     .with(FaultKind::MeterBias { mult: 0.85 }, 600.0, 300.0)
///     .with(FaultKind::FeedLoss { budget_frac: 0.75 }, 1800.0, 300.0);
/// assert_eq!(plan.len(), 2);
/// assert!(!plan.is_empty());
/// // Episodes come back sorted by start time and validated.
/// let events = plan.normalized().unwrap();
/// assert!(events[0].start_s <= events[1].start_s);
/// ```
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// The scheduled episodes (order irrelevant; [`FaultPlan::normalized`]
    /// sorts by start time).
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// An empty plan (injects nothing; bit-identical to no plan at all).
    pub fn new() -> Self {
        FaultPlan { events: Vec::new() }
    }

    /// Builder: append one episode.
    pub fn with(mut self, kind: FaultKind, start_s: f64, duration_s: f64) -> Self {
        self.events.push(FaultEvent { kind, start_s, duration_s });
        self
    }

    /// Scheduled episode count.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Whether the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The episodes sorted by start time, validated: non-negative times
    /// and durations, and no two episodes of the same kind overlapping
    /// (same-kind overlap would make the restore-on-end state ambiguous).
    pub fn normalized(&self) -> anyhow::Result<Vec<FaultEvent>> {
        let mut evs = self.events.clone();
        for e in &evs {
            let bad_start = e.start_s.is_nan() || e.start_s < 0.0;
            let bad_dur = e.duration_s.is_nan() || e.duration_s <= 0.0;
            if bad_start || bad_dur {
                anyhow::bail!(
                    "fault episode '{}' needs start >= 0 and duration > 0 (got {} / {})",
                    e.kind.label(),
                    e.start_s,
                    e.duration_s
                );
            }
        }
        evs.sort_by(|a, b| {
            a.start_s
                .partial_cmp(&b.start_s)
                .unwrap()
                .then(a.duration_s.partial_cmp(&b.duration_s).unwrap())
        });
        // Same-kind overlap must be checked pairwise, not only between
        // neighbors in start order: an interleaved episode of another
        // kind would otherwise hide the conflict (and the restore-on-end
        // handler would un-do a still-active episode mid-run).
        for (i, a) in evs.iter().enumerate() {
            for b in &evs[i + 1..] {
                if a.kind.label() == b.kind.label() && b.start_s < a.end_s() {
                    anyhow::bail!(
                        "overlapping '{}' episodes at {}s and {}s — merge them into one window",
                        a.kind.label(),
                        a.start_s,
                        b.start_s
                    );
                }
            }
        }
        Ok(evs)
    }

    /// A seedable random plan: `episodes` non-overlapping episodes of
    /// random kinds spread over `[0, horizon_s)`. Deterministic given
    /// the seed — the timeline itself is data, so two runs of the same
    /// plan see the same faults at the same instants.
    pub fn random(seed: u64, horizon_s: f64, episodes: usize) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA_17_5EED);
        let mut plan = FaultPlan::new();
        if episodes == 0 || horizon_s <= 0.0 {
            return plan;
        }
        let slot = horizon_s / episodes as f64;
        for i in 0..episodes {
            // Each episode lives in the middle of its own slot, so no
            // two episodes (of any kind) can overlap by construction.
            let start = i as f64 * slot + slot * rng.range_f64(0.1, 0.4);
            let duration = slot * rng.range_f64(0.2, 0.5);
            let kind = match rng.below(5) {
                0 => FaultKind::TelemetryFreeze,
                1 => FaultKind::OobStorm {
                    loss_prob: rng.range_f64(0.5, 0.95),
                    latency_mult: rng.range_f64(2.0, 6.0),
                    jitter_frac: 0.25,
                },
                2 => FaultKind::CapIgnore { server_frac: rng.range_f64(0.25, 1.0) },
                3 => FaultKind::MeterBias { mult: rng.range_f64(0.75, 0.95) },
                _ => FaultKind::FeedLoss { budget_frac: rng.range_f64(0.6, 0.9) },
            };
            plan = plan.with(kind, start, duration);
        }
        plan
    }

    /// Names of the built-in scenarios, in matrix order. "none" is the
    /// control column: an empty plan, bit-identical to the clean run.
    pub fn scenario_names() -> &'static [&'static str] {
        &[
            "none",
            "telemetry-freeze",
            "oob-storm",
            "cap-ignore",
            "meter-bias",
            "feed-loss",
            "cascade",
        ]
    }

    /// A named scenario placed relative to the run horizon: one episode
    /// window in the middle third of the run (so containment is always
    /// observable before the horizon), or a cascade of three. Errors on
    /// unknown names.
    pub fn scenario(name: &str, horizon_s: f64) -> anyhow::Result<FaultPlan> {
        let h = horizon_s;
        let plan = match name {
            "none" => FaultPlan::new(),
            "telemetry-freeze" => {
                FaultPlan::new().with(FaultKind::TelemetryFreeze, 0.30 * h, 0.20 * h)
            }
            "oob-storm" => FaultPlan::new().with(
                FaultKind::OobStorm { loss_prob: 0.85, latency_mult: 4.0, jitter_frac: 0.25 },
                0.30 * h,
                0.20 * h,
            ),
            "cap-ignore" => {
                FaultPlan::new().with(FaultKind::CapIgnore { server_frac: 1.0 }, 0.30 * h, 0.20 * h)
            }
            "meter-bias" => {
                FaultPlan::new().with(FaultKind::MeterBias { mult: 0.80 }, 0.30 * h, 0.20 * h)
            }
            "feed-loss" => {
                FaultPlan::new().with(FaultKind::FeedLoss { budget_frac: 0.75 }, 0.30 * h, 0.20 * h)
            }
            "cascade" => FaultPlan::new()
                .with(FaultKind::TelemetryFreeze, 0.20 * h, 0.10 * h)
                .with(
                    FaultKind::OobStorm { loss_prob: 0.85, latency_mult: 4.0, jitter_frac: 0.25 },
                    0.35 * h,
                    0.15 * h,
                )
                .with(FaultKind::FeedLoss { budget_frac: 0.75 }, 0.55 * h, 0.10 * h),
            other => anyhow::bail!(
                "unknown fault scenario '{other}' (known: {})",
                Self::scenario_names().join(", ")
            ),
        };
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_and_normalize_sort() {
        let plan = FaultPlan::new()
            .with(FaultKind::FeedLoss { budget_frac: 0.8 }, 500.0, 100.0)
            .with(FaultKind::TelemetryFreeze, 100.0, 50.0);
        let evs = plan.normalized().unwrap();
        assert_eq!(evs[0].kind.label(), "telemetry-freeze");
        assert_eq!(evs[1].end_s(), 600.0);
    }

    #[test]
    fn same_kind_overlap_rejected_different_kind_allowed() {
        let bad = FaultPlan::new()
            .with(FaultKind::TelemetryFreeze, 100.0, 200.0)
            .with(FaultKind::TelemetryFreeze, 150.0, 50.0);
        assert!(bad.normalized().is_err());
        let ok = FaultPlan::new()
            .with(FaultKind::TelemetryFreeze, 100.0, 200.0)
            .with(FaultKind::MeterBias { mult: 0.9 }, 150.0, 50.0);
        assert_eq!(ok.normalized().unwrap().len(), 2);
        // An interleaved episode of another kind must not hide a
        // same-kind overlap from validation.
        let hidden = FaultPlan::new()
            .with(FaultKind::FeedLoss { budget_frac: 0.75 }, 0.0, 1000.0)
            .with(FaultKind::TelemetryFreeze, 10.0, 20.0)
            .with(FaultKind::FeedLoss { budget_frac: 0.9 }, 500.0, 100.0);
        assert!(hidden.normalized().is_err());
    }

    #[test]
    fn invalid_times_rejected() {
        assert!(FaultPlan::new()
            .with(FaultKind::TelemetryFreeze, -1.0, 10.0)
            .normalized()
            .is_err());
        assert!(FaultPlan::new()
            .with(FaultKind::TelemetryFreeze, 1.0, 0.0)
            .normalized()
            .is_err());
    }

    #[test]
    fn random_is_deterministic_and_valid() {
        let a = FaultPlan::random(7, 86_400.0, 6);
        let b = FaultPlan::random(7, 86_400.0, 6);
        assert_eq!(a, b);
        assert_eq!(a.len(), 6);
        let evs = a.normalized().unwrap();
        // Slot construction: already in start order, all inside the horizon.
        for (i, e) in evs.iter().enumerate() {
            assert!(e.start_s >= 0.0 && e.end_s() <= 86_400.0, "episode {i}: {e:?}");
        }
        assert_ne!(FaultPlan::random(8, 86_400.0, 6), a);
    }

    #[test]
    fn scenarios_resolve_and_unknown_errors() {
        let h = 10_000.0;
        for name in FaultPlan::scenario_names() {
            let plan = FaultPlan::scenario(name, h).unwrap();
            let evs = plan.normalized().unwrap();
            if *name == "none" {
                assert!(plan.is_empty());
            } else {
                assert!(!plan.is_empty());
                // Every scenario finishes well before the horizon so
                // containment can be observed.
                assert!(evs.iter().all(|e| e.end_s() < 0.9 * h), "{name}: {evs:?}");
            }
        }
        assert!(FaultPlan::scenario("nope", h).is_err());
    }
}
