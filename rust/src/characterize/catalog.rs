//! The LLM catalog (paper Fig 3) with analytic calibration per model.
//!
//! The real models ran on 8×A100 DGX boxes; here each entry carries the
//! parameters that reproduce the paper's *measured shapes*:
//!   * prompt-peak / token-mean anchors (Fig 5),
//!   * frequency sensitivity split by phase (Fig 7: larger models are
//!     more sensitive because their token phase has more compute),
//!   * latency anchors (tokens/s at nominal frequency),
//!   * a training profile (Fig 8/9) for the models trained in the paper.
//!
//! Also includes the vision/multi-modal entries of Fig 19 (§7).

use crate::power::gpu::GpuPowerCalib;
use crate::power::training::TrainingProfile;

/// Model architecture class (Fig 3 taxonomy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelArch {
    /// Encoder-only (RoBERTa-class).
    Encoder,
    /// Decoder-only autoregressive (GPT-class).
    Decoder,
    /// Encoder–decoder (T5-class).
    EncoderDecoder,
    /// Vision model (§7 / Fig 19).
    Vision,
    /// Multi-modal model (§7 / Fig 19).
    Multimodal,
}

/// One catalog entry.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Model name (catalog key).
    pub name: &'static str,
    /// Architecture class.
    pub arch: ModelArch,
    /// Parameter count, billions.
    pub params_b: f64,
    /// GPUs used for inference serving (tensor parallel degree).
    pub infer_gpus: usize,
    /// Measured power-shape calibration (Fig 5 anchors).
    pub power: GpuPowerCalib,
    /// Fraction of prompt-phase time that is compute-bound (scales 1/f).
    pub prompt_compute_frac: f64,
    /// Fraction of token-phase time that is compute-bound. Small for
    /// small models (memory-bound decode), larger for BLOOM-sized models.
    pub token_compute_frac: f64,
    /// Prompt throughput at nominal frequency, tokens/s (whole server).
    pub prompt_tokens_per_s: f64,
    /// Decode speed at nominal frequency, output tokens/s at batch 1.
    pub decode_tokens_per_s: f64,
    /// Training profile if the paper trains this model (Fig 8).
    pub training: Option<TrainingProfile>,
    /// Evaluated for inference in the paper.
    pub inference: bool,
}

impl ModelSpec {
    /// Prompt-phase duration (s) for `input` tokens × `batch` at nominal
    /// frequency. The quadratic attention term grows past ~4k inputs.
    pub fn prompt_time_s(&self, input: f64, batch: f64) -> f64 {
        let toks = input * batch;
        let linear = toks / self.prompt_tokens_per_s;
        // attention quadratic correction, calibrated to keep <4k inputs
        // latency-flat (Fig 5b) and bend beyond
        let quad = linear * (input / 4096.0).max(0.0).powi(2) * 0.35;
        linear + quad
    }

    /// Token-phase duration (s) for `output` tokens at `batch` at nominal
    /// frequency. Batching amortizes weight reads: per-token time grows
    /// only mildly with batch (Fig 5d).
    pub fn token_time_s(&self, output: f64, batch: f64) -> f64 {
        let per_tok = 1.0 / self.decode_tokens_per_s;
        output * per_tok * (1.0 + 0.08 * (batch.max(1.0)).log2())
    }

    /// End-to-end request latency at a frequency ratio r = f/f_max.
    /// Compute-bound fractions stretch as 1/r; memory-bound parts do not.
    pub fn request_latency_s(
        &self,
        input: f64,
        output: f64,
        batch: f64,
        freq_ratio: f64,
    ) -> f64 {
        let r = freq_ratio.clamp(0.05, 1.0);
        let stretch = |t: f64, compute_frac: f64| {
            t * (compute_frac / r + (1.0 - compute_frac))
        };
        stretch(self.prompt_time_s(input, batch), self.prompt_compute_frac)
            + stretch(self.token_time_s(output, batch), self.token_compute_frac)
    }

    /// Relative performance (inverse latency) at a frequency ratio —
    /// the y-axis of Fig 7.
    pub fn relative_perf(&self, input: f64, output: f64, batch: f64, freq_ratio: f64) -> f64 {
        self.request_latency_s(input, output, batch, 1.0)
            / self.request_latency_s(input, output, batch, freq_ratio)
    }
}

/// The full catalog (Fig 3 models + §7 vision/multimodal).
pub fn catalog() -> Vec<ModelSpec> {
    vec![
        ModelSpec {
            name: "RoBERTa",
            arch: ModelArch::Encoder,
            params_b: 0.355,
            infer_gpus: 1,
            power: GpuPowerCalib {
                idle_frac: 0.20,
                prompt_peak_at_256: 0.45,
                prompt_peak_at_8192: 0.70,
                token_mean_at_b1: 0.30,
                token_mean_at_b16: 0.40,
                ..GpuPowerCalib::default()
            },
            prompt_compute_frac: 0.85,
            token_compute_frac: 0.05,
            prompt_tokens_per_s: 800_000.0,
            decode_tokens_per_s: 4000.0, // encoder: "output" is classification
            training: Some(TrainingProfile {
                iter_time_s: 1.0, // §2.4: RoBERTa iteration lasts 1 s
                peak_frac: 0.97,  // does not reach TDP (encoder-only)
                mid_dip_frac: 0.85,
                sync_trough_frac: 0.75, // stays at 75% at iteration boundary
                mid_dip_width: 0.05,
                sync_width: 0.12,
                compute_time_frac: 0.85,
            }),
            inference: true,
        },
        ModelSpec {
            name: "GPT-NeoX-20B",
            arch: ModelArch::Decoder,
            params_b: 20.0,
            infer_gpus: 2,
            power: GpuPowerCalib {
                idle_frac: 0.20,
                prompt_peak_at_256: 0.50,
                prompt_peak_at_8192: 0.92,
                token_mean_at_b1: 0.34,
                token_mean_at_b16: 0.48,
                ..GpuPowerCalib::default()
            },
            prompt_compute_frac: 0.90,
            token_compute_frac: 0.04, // Fig 7: NeoX shows ~no perf loss
            prompt_tokens_per_s: 60_000.0,
            decode_tokens_per_s: 33.0,
            training: Some(TrainingProfile {
                iter_time_s: 2.2,
                peak_frac: 1.05, // beyond TDP (Fig 8)
                mid_dip_frac: 0.78,
                sync_trough_frac: 0.50, // drops to 50% (§2.4)
                mid_dip_width: 0.06,
                sync_width: 0.15,
                compute_time_frac: 0.80,
            }),
            inference: true,
        },
        ModelSpec {
            name: "OPT-30B",
            arch: ModelArch::Decoder,
            params_b: 30.0,
            infer_gpus: 4,
            power: GpuPowerCalib {
                idle_frac: 0.20,
                prompt_peak_at_256: 0.55,
                prompt_peak_at_8192: 0.97,
                token_mean_at_b1: 0.37,
                token_mean_at_b16: 0.52,
                ..GpuPowerCalib::default()
            },
            prompt_compute_frac: 0.90,
            token_compute_frac: 0.08,
            prompt_tokens_per_s: 45_000.0,
            decode_tokens_per_s: 28.0,
            training: None, // inference only (Fig 3 asterisk)
            inference: true,
        },
        ModelSpec {
            name: "BLOOM-176B",
            arch: ModelArch::Decoder,
            params_b: 176.0,
            infer_gpus: 8,
            power: GpuPowerCalib {
                idle_frac: 0.20,
                prompt_peak_at_256: 0.72,
                prompt_peak_at_8192: 1.10, // spikes beyond TDP (Fig 4/5)
                token_mean_at_b1: 0.45,
                token_mean_at_b16: 0.62,
                ..GpuPowerCalib::default()
            },
            prompt_compute_frac: 0.92,
            token_compute_frac: 0.22, // Fig 7: BLOOM loses ~5% at 13% power cut
            prompt_tokens_per_s: 11_000.0,
            decode_tokens_per_s: 16.0,
            training: None, // inference only
            inference: true,
        },
        ModelSpec {
            name: "Flan-T5-XXL",
            arch: ModelArch::EncoderDecoder,
            params_b: 11.0,
            infer_gpus: 2,
            power: GpuPowerCalib {
                idle_frac: 0.20,
                prompt_peak_at_256: 0.48,
                prompt_peak_at_8192: 0.88,
                token_mean_at_b1: 0.33,
                token_mean_at_b16: 0.46,
                ..GpuPowerCalib::default()
            },
            prompt_compute_frac: 0.88,
            token_compute_frac: 0.06,
            prompt_tokens_per_s: 90_000.0,
            decode_tokens_per_s: 40.0,
            training: Some(TrainingProfile {
                iter_time_s: 3.0,
                peak_frac: 1.08, // beyond TDP (Fig 8)
                mid_dip_frac: 0.60,
                sync_trough_frac: 0.20, // all the way to idle (§2.4)
                mid_dip_width: 0.08,
                sync_width: 0.20,
                compute_time_frac: 0.75,
            }),
            inference: true,
        },
        // ---- §7 / Fig 19: vision + multimodal ---------------------------
        ModelSpec {
            name: "ViT-L-train",
            arch: ModelArch::Vision,
            params_b: 0.3,
            infer_gpus: 1,
            power: GpuPowerCalib {
                idle_frac: 0.20,
                prompt_peak_at_256: 0.80,
                prompt_peak_at_8192: 0.95,
                token_mean_at_b1: 0.75, // vision: stable, high utilization
                token_mean_at_b16: 0.85,
                ..GpuPowerCalib::default()
            },
            prompt_compute_frac: 0.92,
            token_compute_frac: 0.85, // fully compute-bound: linear-ish curve
            prompt_tokens_per_s: 500_000.0,
            decode_tokens_per_s: 2000.0,
            training: Some(TrainingProfile {
                iter_time_s: 0.8,
                peak_frac: 1.00,
                mid_dip_frac: 0.85,
                sync_trough_frac: 0.70,
                mid_dip_width: 0.05,
                sync_width: 0.10,
                compute_time_frac: 0.90,
            }),
            inference: false,
        },
        ModelSpec {
            name: "CLIP-infer",
            arch: ModelArch::Multimodal,
            params_b: 0.4,
            infer_gpus: 1,
            power: GpuPowerCalib {
                idle_frac: 0.20,
                prompt_peak_at_256: 0.70,
                prompt_peak_at_8192: 0.85,
                token_mean_at_b1: 0.65,
                token_mean_at_b16: 0.75,
                ..GpuPowerCalib::default()
            },
            prompt_compute_frac: 0.88,
            token_compute_frac: 0.60,
            prompt_tokens_per_s: 600_000.0,
            decode_tokens_per_s: 3000.0,
            training: None,
            inference: true,
        },
    ]
}

/// Look a model up by name.
pub fn find(name: &str) -> Option<ModelSpec> {
    catalog().into_iter().find(|m| m.name == name)
}

/// The language models the paper evaluates for inference.
pub fn inference_models() -> Vec<ModelSpec> {
    catalog()
        .into_iter()
        .filter(|m| m.inference && !matches!(m.arch, ModelArch::Vision | ModelArch::Multimodal))
        .collect()
}

/// The language models the paper trains (Fig 8 profiles).
pub fn training_models() -> Vec<ModelSpec> {
    catalog()
        .into_iter()
        .filter(|m| m.training.is_some() && !matches!(m.arch, ModelArch::Vision | ModelArch::Multimodal))
        .collect()
}

/// The §7 vision/multimodal entries (Fig 19).
pub fn vision_models() -> Vec<ModelSpec> {
    catalog()
        .into_iter()
        .filter(|m| matches!(m.arch, ModelArch::Vision | ModelArch::Multimodal))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_covers_fig3() {
        let names: Vec<_> = catalog().iter().map(|m| m.name).collect();
        for required in ["RoBERTa", "GPT-NeoX-20B", "OPT-30B", "BLOOM-176B", "Flan-T5-XXL"] {
            assert!(names.contains(&required), "{required} missing");
        }
        assert_eq!(inference_models().len(), 5);
        assert_eq!(training_models().len(), 3); // RoBERTa, NeoX, Flan-T5
        assert_eq!(vision_models().len(), 2);
    }

    #[test]
    fn larger_models_draw_more_power() {
        // Fig 5: "larger models show significantly larger peak and mean".
        let neox = find("GPT-NeoX-20B").unwrap();
        let bloom = find("BLOOM-176B").unwrap();
        assert!(bloom.power.prompt_peak_frac(2048.0) > neox.power.prompt_peak_frac(2048.0));
        assert!(bloom.power.token_mean_frac(1.0) > neox.power.token_mean_frac(1.0));
    }

    #[test]
    fn latency_flat_until_4k_inputs() {
        // Fig 5b: input size barely moves latency until >4k tokens.
        let bloom = find("BLOOM-176B").unwrap();
        let l256 = bloom.request_latency_s(256.0, 128.0, 1.0, 1.0);
        let l4k = bloom.request_latency_s(4096.0, 128.0, 1.0, 1.0);
        let l8k = bloom.request_latency_s(8192.0, 128.0, 1.0, 1.0);
        assert!((l4k - l256) / l256 < 0.20, "l256={l256} l4k={l4k}");
        assert!(l8k / l4k > 1.1, "quadratic bend expected beyond 4k");
    }

    #[test]
    fn latency_linear_in_output() {
        // Fig 5f: output size stretches latency linearly.
        let bloom = find("BLOOM-176B").unwrap();
        let l128 = bloom.request_latency_s(1024.0, 128.0, 1.0, 1.0);
        let l256 = bloom.request_latency_s(1024.0, 256.0, 1.0, 1.0);
        let l512 = bloom.request_latency_s(1024.0, 512.0, 1.0, 1.0);
        let d1 = l256 - l128;
        let d2 = l512 - l256;
        assert!((d2 / d1 - 2.0).abs() < 0.05, "d1={d1} d2={d2}");
    }

    #[test]
    fn fig7_superlinearity_neox_vs_bloom() {
        // Fig 7: at similar peak-power reduction (~13%), NeoX loses ~0%
        // performance while BLOOM loses ~5%.
        let neox = find("GPT-NeoX-20B").unwrap();
        let bloom = find("BLOOM-176B").unwrap();
        let r = 1110.0 / 1410.0;
        let neox_loss = 1.0 - neox.relative_perf(2048.0, 512.0, 1.0, r);
        let bloom_loss = 1.0 - bloom.relative_perf(2048.0, 512.0, 1.0, r);
        assert!(neox_loss < 0.03, "neox_loss={neox_loss}");
        assert!((0.02..0.10).contains(&bloom_loss), "bloom_loss={bloom_loss}");
        // power reduction must exceed perf loss (superlinear claim)
        let bloom_power_red = 1.0
            - bloom.power.apply_freq(bloom.power.prompt_peak_frac(2048.0), 1110.0)
                / bloom.power.prompt_peak_frac(2048.0);
        assert!(bloom_power_red > bloom_loss * 1.5);
    }

    #[test]
    fn fig7b_smaller_inputs_less_sensitive() {
        // Fig 7b: smaller total input => less perf loss at equal capping.
        let bloom = find("BLOOM-176B").unwrap();
        let r = 1110.0 / 1410.0;
        let loss_small = 1.0 - bloom.relative_perf(512.0, 512.0, 1.0, r);
        let loss_large = 1.0 - bloom.relative_perf(8192.0, 512.0, 1.0, r);
        assert!(loss_small < loss_large, "{loss_small} vs {loss_large}");
    }

    #[test]
    fn vision_models_scale_linearly_with_freq() {
        // Fig 19: vision/multimodal are compute-bound; perf tracks power.
        let vit = find("ViT-L-train").unwrap();
        let r = 1110.0 / 1410.0;
        let loss = 1.0 - vit.relative_perf(256.0, 256.0, 8.0, r);
        // near-linear: perf loss close to frequency reduction (21%)
        assert!((0.12..0.22).contains(&loss), "loss={loss}");
    }

    #[test]
    fn training_profiles_match_section_2_4() {
        let roberta = find("RoBERTa").unwrap().training.unwrap();
        let neox = find("GPT-NeoX-20B").unwrap().training.unwrap();
        let flant5 = find("Flan-T5-XXL").unwrap().training.unwrap();
        assert_eq!(roberta.sync_trough_frac, 0.75);
        assert_eq!(neox.sync_trough_frac, 0.50);
        assert_eq!(flant5.sync_trough_frac, 0.20);
        assert!(roberta.peak_frac < 1.0); // RoBERTa does not reach TDP
        assert!(neox.peak_frac > 1.0 && flant5.peak_frac > 1.0);
    }
}
