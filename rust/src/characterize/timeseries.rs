//! Server-level power timeseries synthesis — reproduces the waveforms of
//! Fig 4 (inference: spiky prompt phase, long stable token phase) and
//! Fig 8 (training: plateau / dip / trough, under no cap, power cap, and
//! frequency cap), sampled at the paper's 100 ms DCGM interval.

use crate::characterize::catalog::ModelSpec;
use crate::power::gpu::{CapMode, Phase};
use crate::power::training::TrainingPowerModel;
use crate::util::rng::Rng;

/// One sampled point: (time_s, gpu_power_fraction_of_tdp).
pub type Sample = (f64, f64);

/// Synthesize the Fig 4 waveform: `n_inferences` back-to-back requests of
/// the same prompt on a dedicated server, sampled every `dt` seconds.
/// Small measurement noise replicates DCGM jitter.
pub fn inference_timeseries(
    model: &ModelSpec,
    input: f64,
    output: f64,
    batch: f64,
    n_inferences: usize,
    dt: f64,
    seed: u64,
) -> Vec<Sample> {
    let mut rng = Rng::new(seed);
    let prompt_t = model.prompt_time_s(input, batch);
    let token_t = model.token_time_s(output, batch);
    let gap_t = 0.4; // scheduling gap between requests
    let total = n_inferences as f64 * (prompt_t + token_t + gap_t);
    let mut out = Vec::with_capacity((total / dt) as usize + 1);
    let mut t = 0.0;
    while t < total {
        let cycle = prompt_t + token_t + gap_t;
        let x = t % cycle;
        let phase = if x < prompt_t {
            Phase::Prompt { total_input: input * batch }
        } else if x < prompt_t + token_t {
            Phase::Token { batch }
        } else {
            Phase::Idle
        };
        let mut p = model.power.phase_power(phase, CapMode::None, false);
        // DCGM-style sampling noise; spikes jitter more than steady state.
        let noise = match phase {
            Phase::Prompt { .. } => 0.04,
            Phase::Token { .. } => 0.015,
            Phase::Idle => 0.005,
        };
        p += rng.normal_with(0.0, noise);
        out.push((t, p.max(0.0)));
        t += dt;
    }
    out
}

/// Synthesize the Fig 8 waveform: `n_iters` training iterations under a
/// given cap, sampled every `dt` seconds.
pub fn training_timeseries(
    model: &ModelSpec,
    cap: CapMode,
    n_iters: usize,
    dt: f64,
    seed: u64,
) -> Vec<Sample> {
    let profile = model
        .training
        .expect("model has no training profile");
    let tm = TrainingPowerModel { profile, calib: model.power };
    let mut rng = Rng::new(seed);
    let iter_t = tm.iter_time_s(cap);
    let total = n_iters as f64 * iter_t;
    let mut out = Vec::with_capacity((total / dt) as usize + 1);
    let mut t = 0.0;
    while t < total {
        let p = tm.power_frac_at(t % iter_t, cap) + rng.normal_with(0.0, 0.02);
        out.push((t, p.max(0.0)));
        t += dt;
    }
    out
}

/// Summary statistics of a timeseries (peak, mean, trough).
pub fn summarize(samples: &[Sample]) -> (f64, f64, f64) {
    let mut peak = f64::NEG_INFINITY;
    let mut trough = f64::INFINITY;
    let mut sum = 0.0;
    for &(_, p) in samples {
        peak = peak.max(p);
        trough = trough.min(p);
        sum += p;
    }
    (peak, sum / samples.len() as f64, trough)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::characterize::catalog::find;

    #[test]
    fn inference_waveform_has_spike_then_stable() {
        let bloom = find("BLOOM-176B").unwrap();
        let ts = inference_timeseries(&bloom, 2048.0, 256.0, 1.0, 3, 0.1, 42);
        let (peak, mean, _) = summarize(&ts);
        // spike well above the mean — Fig 4's signature
        assert!(peak > mean * 1.4, "peak={peak} mean={mean}");
        // token phase dominates time, so mean is near the token level
        let token_level = bloom.power.token_mean_frac(1.0);
        assert!((mean - token_level).abs() < 0.12, "mean={mean} token={token_level}");
    }

    #[test]
    fn inference_spike_duration_is_short() {
        // §2.3: "the resulting power spike per request generally lasts <1s"
        let bloom = find("BLOOM-176B").unwrap();
        let prompt_t = bloom.prompt_time_s(2048.0, 1.0);
        assert!(prompt_t < 1.0, "prompt_t={prompt_t}");
        // and the token phase is much longer
        assert!(bloom.token_time_s(256.0, 1.0) > 5.0 * prompt_t);
    }

    #[test]
    fn deterministic_per_seed() {
        let m = find("GPT-NeoX-20B").unwrap();
        let a = inference_timeseries(&m, 1024.0, 128.0, 1.0, 2, 0.1, 7);
        let b = inference_timeseries(&m, 1024.0, 128.0, 1.0, 2, 0.1, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn training_waveform_caps_reduce_peak() {
        let flant5 = find("Flan-T5-XXL").unwrap();
        let none = training_timeseries(&flant5, CapMode::None, 5, 0.1, 1);
        let freq = training_timeseries(&flant5, CapMode::FreqCap { mhz: 1110.0 }, 5, 0.1, 1);
        let (p0, _, t0) = summarize(&none);
        let (p1, _, t1) = summarize(&freq);
        assert!(p1 < p0 * 0.92, "freq cap should cut peak: {p0} -> {p1}");
        // troughs (idle) barely move for Flan-T5
        assert!((t1 - t0).abs() < 0.08, "troughs {t0} vs {t1}");
    }

    #[test]
    fn training_iterations_stretch_under_cap() {
        let neox = find("GPT-NeoX-20B").unwrap();
        let none = training_timeseries(&neox, CapMode::None, 5, 0.1, 2);
        let freq = training_timeseries(&neox, CapMode::FreqCap { mhz: 1110.0 }, 5, 0.1, 2);
        assert!(freq.len() > none.len(), "capped run must take longer");
    }
}
