//! §2 characterization substrate: the catalog of LLMs the paper measures
//! (Fig 3) with per-model power/latency calibrations, plus the
//! server-level power-timeseries synthesis behind Figs 4 and 8.

pub mod catalog;
pub mod timeseries;

pub use catalog::{ModelArch, ModelSpec, catalog, find, inference_models, training_models, vision_models};
