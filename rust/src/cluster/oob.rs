//! Out-of-band GPU control path: power-manager → rack manager → BMC →
//! SMBPBI (§4.D/E, Fig 12). The defining property is *latency*: frequency
//! and power caps take ~40 s to apply; only the hardware powerbrake is
//! fast (~5 s). POLCA's two-threshold policy exists to absorb exactly
//! this gap. The channel also models (optional) unreliability: command
//! loss forces the policy to be idempotent and re-issued.

use crate::cluster::hierarchy::Priority;
use crate::util::rng::Rng;

/// A control command addressed to a set of servers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OobCommand {
    /// Cap SM frequency of every GPU on servers with the given priority.
    FreqCap { target: Priority, mhz: f64 },
    /// Remove the frequency cap for the given priority class.
    Uncap { target: Priority },
    /// Hardware powerbrake: all GPUs to near-halt (288 MHz on A100).
    PowerBrake,
    /// Release the powerbrake.
    ReleaseBrake,
}

impl OobCommand {
    /// Whether this command rides the fast (brake) path.
    pub fn is_brake_path(&self) -> bool {
        matches!(self, OobCommand::PowerBrake | OobCommand::ReleaseBrake)
    }
}

/// A command in flight, to be applied at `apply_at_s`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PendingCommand {
    /// When the command entered the channel.
    pub issued_at_s: f64,
    /// When it takes effect (issue time + path latency + jitter).
    pub apply_at_s: f64,
    /// The command itself.
    pub cmd: OobCommand,
}

/// The OOB channel: issue commands, poll which have taken effect.
///
/// ```
/// use polca::cluster::oob::{OobChannel, OobCommand};
///
/// let mut ch = OobChannel::new(40.0, 5.0, 1);
/// // The brake rides the dedicated 5 s fast path...
/// let apply_at = ch.issue(0.0, OobCommand::PowerBrake).unwrap();
/// assert_eq!(apply_at, 5.0);
/// // ...and a latency storm on the management network (fault
/// // injection) stretches only the slow cap path.
/// ch.set_latency_mult(4.0);
/// assert_eq!(ch.issue(0.0, OobCommand::PowerBrake), Some(5.0));
/// assert_eq!(ch.issue(0.0, OobCommand::ReleaseBrake), Some(5.0));
/// assert_eq!(ch.due(5.0).len(), 3);
/// ```
#[derive(Debug, Clone)]
pub struct OobChannel {
    /// Cap/uncap apply latency (Table 1: 40 s).
    pub cap_latency_s: f64,
    /// Powerbrake apply latency (Table 1: 5 s).
    pub brake_latency_s: f64,
    /// Probability a non-brake command is silently lost (reliability
    /// model; 0.0 in the paper's default but exercised in failure tests).
    pub loss_prob: f64,
    /// Latency jitter fraction (uniform ±).
    pub jitter_frac: f64,
    /// Multiplier on the slow-path latency (1.0 = nominal; raised during
    /// a scheduled latency storm, [`crate::faults::FaultKind::OobStorm`]).
    /// The brake path is a hardware signal and is never stretched.
    pub latency_mult: f64,
    pending: Vec<PendingCommand>,
    rng: Rng,
}

impl OobChannel {
    /// A reliable channel with the given path latencies (Table 1).
    pub fn new(cap_latency_s: f64, brake_latency_s: f64, seed: u64) -> Self {
        OobChannel {
            cap_latency_s,
            brake_latency_s,
            loss_prob: 0.0,
            jitter_frac: 0.0,
            latency_mult: 1.0,
            pending: Vec::new(),
            rng: Rng::new(seed),
        }
    }

    /// Add command loss and latency jitter (failure-mode studies).
    pub fn with_unreliability(mut self, loss_prob: f64, jitter_frac: f64) -> Self {
        self.set_unreliability(loss_prob, jitter_frac);
        self
    }

    /// Set command loss and latency jitter in place — the scheduled-
    /// episode form of [`OobChannel::with_unreliability`]: a fault plan
    /// raises these at an episode start and restores the baseline at
    /// its end.
    pub fn set_unreliability(&mut self, loss_prob: f64, jitter_frac: f64) {
        self.loss_prob = loss_prob;
        self.jitter_frac = jitter_frac;
    }

    /// Set the slow-path latency multiplier (storm episodes; 1.0 =
    /// nominal). Commands already in flight keep their apply times.
    pub fn set_latency_mult(&mut self, mult: f64) {
        self.latency_mult = mult.max(0.0);
    }

    /// Issue a command at time `now`; returns when it will apply, or None
    /// if the channel dropped it. The brake path is never dropped (it is
    /// a dedicated hardware signal, §4: "extremely reliable").
    pub fn issue(&mut self, now_s: f64, cmd: OobCommand) -> Option<f64> {
        if !cmd.is_brake_path() && self.loss_prob > 0.0 && self.rng.bool(self.loss_prob) {
            return None;
        }
        let base = if cmd.is_brake_path() {
            self.brake_latency_s
        } else {
            self.cap_latency_s * self.latency_mult
        };
        let jitter = if self.jitter_frac > 0.0 {
            base * self.jitter_frac * (2.0 * self.rng.f64() - 1.0)
        } else {
            0.0
        };
        let apply_at = now_s + (base + jitter).max(0.0);
        self.pending.push(PendingCommand { issued_at_s: now_s, apply_at_s: apply_at, cmd });
        Some(apply_at)
    }

    /// Drain every command whose apply time has arrived, in apply order.
    pub fn due(&mut self, now_s: f64) -> Vec<PendingCommand> {
        let mut due: Vec<PendingCommand> =
            self.pending.iter().copied().filter(|p| p.apply_at_s <= now_s).collect();
        self.pending.retain(|p| p.apply_at_s > now_s);
        due.sort_by(|a, b| a.apply_at_s.partial_cmp(&b.apply_at_s).unwrap());
        due
    }

    /// Earliest pending apply time (for event scheduling).
    pub fn next_apply(&self) -> Option<f64> {
        self.pending.iter().map(|p| p.apply_at_s).fold(None, |acc, t| match acc {
            None => Some(t),
            Some(a) => Some(a.min(t)),
        })
    }

    /// Commands issued but not yet applied.
    pub fn in_flight(&self) -> usize {
        self.pending.len()
    }

    /// Is a command of this kind already in flight? (The manager avoids
    /// spamming the slow channel with duplicates.)
    pub fn has_pending(&self, pred: impl Fn(&OobCommand) -> bool) -> bool {
        self.pending.iter().any(|p| pred(&p.cmd))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cap_takes_40s_brake_takes_5s() {
        let mut ch = OobChannel::new(40.0, 5.0, 0);
        let t_cap = ch
            .issue(100.0, OobCommand::FreqCap { target: Priority::Low, mhz: 1275.0 })
            .unwrap();
        let t_brake = ch.issue(100.0, OobCommand::PowerBrake).unwrap();
        assert_eq!(t_cap, 140.0);
        assert_eq!(t_brake, 105.0);
        // Nothing due yet.
        assert!(ch.due(104.0).is_empty());
        // Brake applies first despite being issued second.
        let due = ch.due(141.0);
        assert_eq!(due.len(), 2);
        assert_eq!(due[0].cmd, OobCommand::PowerBrake);
        assert_eq!(ch.in_flight(), 0);
    }

    #[test]
    fn next_apply_tracks_earliest() {
        let mut ch = OobChannel::new(40.0, 5.0, 0);
        assert_eq!(ch.next_apply(), None);
        ch.issue(0.0, OobCommand::FreqCap { target: Priority::High, mhz: 1305.0 });
        ch.issue(0.0, OobCommand::PowerBrake);
        assert_eq!(ch.next_apply(), Some(5.0));
    }

    #[test]
    fn lossy_channel_drops_caps_not_brakes() {
        let mut ch = OobChannel::new(40.0, 5.0, 3).with_unreliability(1.0, 0.0);
        assert!(ch.issue(0.0, OobCommand::FreqCap { target: Priority::Low, mhz: 1110.0 }).is_none());
        assert!(ch.issue(0.0, OobCommand::PowerBrake).is_some());
    }

    #[test]
    fn jitter_bounded() {
        let mut ch = OobChannel::new(40.0, 5.0, 7).with_unreliability(0.0, 0.25);
        for _ in 0..100 {
            let t = ch.issue(0.0, OobCommand::Uncap { target: Priority::Low }).unwrap();
            assert!((30.0..=50.0).contains(&t), "t={t}");
        }
    }

    #[test]
    fn latency_storm_stretches_caps_not_brakes() {
        let mut ch = OobChannel::new(40.0, 5.0, 0);
        ch.set_latency_mult(4.0);
        let t_cap = ch
            .issue(0.0, OobCommand::FreqCap { target: Priority::Low, mhz: 1110.0 })
            .unwrap();
        let t_brake = ch.issue(0.0, OobCommand::PowerBrake).unwrap();
        assert_eq!(t_cap, 160.0);
        assert_eq!(t_brake, 5.0);
        // Restoring the baseline ends the storm for new commands only.
        ch.set_latency_mult(1.0);
        let t_cap2 = ch.issue(0.0, OobCommand::Uncap { target: Priority::Low }).unwrap();
        assert_eq!(t_cap2, 40.0);
        // The storm-era command keeps its stretched apply time.
        assert!(ch.has_pending(|c| matches!(c, OobCommand::FreqCap { .. })));
        assert_eq!(ch.due(41.0).len(), 2); // brake + the post-storm uncap
    }

    #[test]
    fn set_unreliability_episodes_toggle_loss() {
        let mut ch = OobChannel::new(40.0, 5.0, 3);
        ch.set_unreliability(1.0, 0.0);
        assert!(ch.issue(0.0, OobCommand::Uncap { target: Priority::High }).is_none());
        ch.set_unreliability(0.0, 0.0);
        assert!(ch.issue(0.0, OobCommand::Uncap { target: Priority::High }).is_some());
    }

    #[test]
    fn has_pending_predicate() {
        let mut ch = OobChannel::new(40.0, 5.0, 0);
        ch.issue(0.0, OobCommand::FreqCap { target: Priority::Low, mhz: 1275.0 });
        assert!(ch.has_pending(|c| matches!(c, OobCommand::FreqCap { .. })));
        assert!(!ch.has_pending(|c| matches!(c, OobCommand::PowerBrake)));
    }
}
