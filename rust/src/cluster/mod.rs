//! Datacenter power-delivery substrate: the row/rack/server hierarchy
//! (Fig 10), PDU telemetry with sampling delay, and the slow out-of-band
//! control path (BMC / SMBPBI) with the latencies of Table 1 — the
//! constraints that shape POLCA's double-threshold design (§4/§5).

pub mod hierarchy;
pub mod oob;
pub mod telemetry;

pub use hierarchy::{Priority, Row, Server};
pub use oob::{OobChannel, OobCommand, PendingCommand};
pub use telemetry::{SpikeStats, TelemetryBuffer};
