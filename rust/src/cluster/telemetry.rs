//! PDU row-power telemetry: delayed sampling (the power manager sees
//! readings `telemetry_delay_s` late, Table 1) and the windowed spike
//! statistics of Table 2 (max/P99/P90 power rise within 2 s / 5 s / 40 s)
//! that POLCA's threshold choice depends on (§5.E).

use std::collections::VecDeque;

use crate::util::stats::{max_rise_within, Percentiles};

/// Ring buffer of (time_s, normalized_row_power) samples with delayed
/// read semantics.
///
/// ```
/// use polca::cluster::telemetry::TelemetryBuffer;
///
/// let mut tb = TelemetryBuffer::new(2.0, 60.0);
/// tb.record(0.0, 0.70);
/// tb.record(2.0, 0.80);
/// tb.record(4.0, 0.90);
/// // The power manager reads 2 s late: at t=4 it sees the t=2 sample.
/// assert_eq!(tb.visible_at(4.0), Some((2.0, 0.80)));
/// // A dropout window pins visibility to what was visible at its start.
/// tb.freeze(4.0, 10.0);
/// tb.record(6.0, 1.00);
/// assert_eq!(tb.visible_at(6.0), Some((2.0, 0.80)));
/// // After the window, the fresh backlog becomes visible again.
/// assert_eq!(tb.visible_at(10.0), Some((6.0, 1.00)));
/// ```
#[derive(Debug, Clone)]
pub struct TelemetryBuffer {
    samples: VecDeque<(f64, f64)>,
    /// How long readings take to reach the power manager.
    pub delay_s: f64,
    /// Retention horizon for spike statistics.
    pub retain_s: f64,
    /// Active dropout window with the reading pinned for its duration:
    /// `(from_s, until_s, sample visible at from_s)`. The sample is
    /// captured at freeze time so retention pruning during a long
    /// window can never turn the stale reading into no reading.
    freeze: Option<(f64, f64, Option<(f64, f64)>)>,
}

impl TelemetryBuffer {
    /// Empty buffer with the given read delay and retention horizon.
    pub fn new(delay_s: f64, retain_s: f64) -> Self {
        TelemetryBuffer { samples: VecDeque::new(), delay_s, retain_s, freeze: None }
    }

    /// Start a telemetry dropout: for reads in `[from_s, until_s)` the
    /// power manager keeps seeing whatever was visible at `from_s` (the
    /// meter keeps recording ground truth throughout). A later call
    /// replaces any previous window.
    pub fn freeze(&mut self, from_s: f64, until_s: f64) {
        self.freeze = None; // pin against the normal (unfrozen) view
        let pinned = self.visible_at(from_s);
        self.freeze = Some((from_s, until_s, pinned));
    }

    /// Record an instantaneous PDU reading at time `t`.
    pub fn record(&mut self, t: f64, normalized_power: f64) {
        debug_assert!(self.samples.back().map(|&(pt, _)| t >= pt).unwrap_or(true));
        self.samples.push_back((t, normalized_power));
        let horizon = t - self.retain_s;
        while let Some(&(pt, _)) = self.samples.front() {
            if pt < horizon && self.samples.len() > 1 {
                self.samples.pop_front();
            } else {
                break;
            }
        }
    }

    /// What the power manager sees at time `t`: the newest sample that is
    /// at least `delay_s` old. None until the pipeline fills. During a
    /// [`TelemetryBuffer::freeze`] window the answer is pinned to the
    /// window's start — the reading goes *stale*, it does not go away.
    pub fn visible_at(&self, t: f64) -> Option<(f64, f64)> {
        if let Some((from, until, pinned)) = self.freeze {
            if t >= from && t < until {
                return pinned;
            }
        }
        let cutoff = t - self.delay_s;
        self.samples.iter().rev().find(|&&(st, _)| st <= cutoff).copied()
    }

    /// Latest ground-truth sample (for the breaker/UPS, which see real
    /// power immediately).
    pub fn latest(&self) -> Option<(f64, f64)> {
        self.samples.back().copied()
    }

    /// Retained sample count.
    pub fn len(&self) -> usize {
        self.samples.len()
    }
    /// Whether no samples are retained.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Values in chronological order, allocation-free (the hot path for
    /// the per-run statistics; prefer this over [`TelemetryBuffer::values`]).
    pub fn iter_values(&self) -> impl Iterator<Item = f64> + '_ {
        self.samples.iter().map(|&(_, p)| p)
    }

    /// Values in chronological order as a fresh `Vec` (export paths;
    /// statistics use [`TelemetryBuffer::iter_values`] or a caller-owned
    /// scratch buffer via [`TelemetryBuffer::spike_stats_with`] instead).
    pub fn values(&self) -> Vec<f64> {
        self.iter_values().collect()
    }

    /// Sampling period estimate from the buffer.
    fn period_s(&self) -> f64 {
        if self.samples.len() < 2 {
            return f64::NAN;
        }
        let (t0, _) = self.samples.front().unwrap();
        let (t1, _) = self.samples.back().unwrap();
        (t1 - t0) / (self.samples.len() - 1) as f64
    }

    /// Table 2 spike statistics over the retained window (allocates a
    /// fresh scratch buffer; callers on a hot loop should hold one and
    /// use [`TelemetryBuffer::spike_stats_with`]).
    pub fn spike_stats(&self, windows_s: &[f64]) -> Vec<SpikeStats> {
        let mut scratch = Vec::new();
        self.spike_stats_with(windows_s, &mut scratch)
    }

    /// Table 2 spike statistics, reusing `scratch` for the contiguous
    /// sample copy the sliding-window scan needs (cleared and refilled;
    /// repeated calls amortize the allocation to zero).
    pub fn spike_stats_with(&self, windows_s: &[f64], scratch: &mut Vec<f64>) -> Vec<SpikeStats> {
        scratch.clear();
        scratch.extend(self.iter_values());
        let period = self.period_s();
        windows_s
            .iter()
            .map(|&w| {
                let nsamples =
                    if period.is_nan() { 1 } else { (w / period).round().max(1.0) as usize };
                SpikeStats { window_s: w, max_rise: max_rise_within(scratch, nsamples) }
            })
            .collect()
    }

    /// Peak and percentile utilization over the retained window.
    pub fn utilization(&self) -> (f64, f64, f64) {
        let mut p = Percentiles::new();
        for v in self.iter_values() {
            p.push(v);
        }
        (p.max(), p.p99(), p.mean())
    }
}

/// Max power rise within a time window (normalized units).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpikeStats {
    /// The window the rise was measured over, seconds.
    pub window_s: f64,
    /// Largest power rise observed within the window.
    pub max_rise: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delayed_visibility() {
        let mut tb = TelemetryBuffer::new(2.0, 100.0);
        tb.record(0.0, 0.5);
        tb.record(1.0, 0.6);
        tb.record(2.0, 0.7);
        tb.record(3.0, 0.8);
        // At t=3, only samples <= 1.0 are visible.
        assert_eq!(tb.visible_at(3.0), Some((1.0, 0.6)));
        // Before the pipeline fills, nothing is visible.
        assert_eq!(tb.visible_at(2.0), Some((0.0, 0.5)));
        assert_eq!(tb.visible_at(1.5), None);
        assert_eq!(tb.visible_at(-1.0), None);
        // Ground truth is immediate.
        assert_eq!(tb.latest(), Some((3.0, 0.8)));
    }

    #[test]
    fn retention_evicts_old() {
        let mut tb = TelemetryBuffer::new(0.0, 10.0);
        for i in 0..100 {
            tb.record(i as f64, 0.5);
        }
        assert!(tb.len() <= 12, "len={}", tb.len());
    }

    #[test]
    fn spike_stats_windows() {
        let mut tb = TelemetryBuffer::new(0.0, 1000.0);
        // 2s sampling; a spike of +0.3 that takes 3 samples (6s) to build
        let series = [0.5, 0.5, 0.5, 0.6, 0.7, 0.8, 0.5, 0.5];
        for (i, &v) in series.iter().enumerate() {
            tb.record(i as f64 * 2.0, v);
        }
        let stats = tb.spike_stats(&[2.0, 40.0]);
        // within 2s (1 sample): max adjacent rise = 0.1
        assert!((stats[0].max_rise - 0.1).abs() < 1e-12);
        // within 40s (20 samples): full rise 0.3
        assert!((stats[1].max_rise - 0.3).abs() < 1e-12);
        assert!(stats[1].max_rise >= stats[0].max_rise);
    }

    #[test]
    fn freeze_window_pins_then_releases_visibility() {
        let mut tb = TelemetryBuffer::new(2.0, 100.0);
        for i in 0..10 {
            tb.record(i as f64, 0.5 + 0.01 * i as f64);
        }
        let at = |i: i32| Some((i as f64, 0.5 + 0.01 * i as f64));
        assert_eq!(tb.visible_at(9.0), at(7));
        tb.freeze(9.0, 14.0);
        for i in 10..16 {
            tb.record(i as f64, 0.5 + 0.01 * i as f64);
        }
        // Inside the window: pinned to what was visible at 9.0.
        assert_eq!(tb.visible_at(10.0), at(7));
        assert_eq!(tb.visible_at(13.9), at(7));
        // After the window: the normal 2 s delay resumes.
        assert_eq!(tb.visible_at(14.0), at(12));
        // Ground truth never froze.
        assert_eq!(tb.latest(), at(15));
    }

    #[test]
    fn frozen_reading_survives_retention_pruning() {
        // A dropout longer than the retention horizon: the pinned
        // sample is evicted from the buffer, but the stale reading must
        // stay readable — "the reading goes stale, it does not go away".
        let mut tb = TelemetryBuffer::new(2.0, 60.0);
        for i in 0..=50 {
            tb.record(i as f64 * 2.0, 0.5);
        }
        tb.freeze(100.0, 300.0);
        let pinned = tb.visible_at(150.0);
        assert_eq!(pinned, Some((98.0, 0.5)));
        // Keep recording well past the retention horizon.
        for i in 51..=120 {
            tb.record(i as f64 * 2.0, 0.9);
        }
        assert_eq!(tb.visible_at(230.0), pinned, "stale, not gone");
        // After the window the backlog (newest retained sample at
        // t=240) is visible again.
        assert_eq!(tb.visible_at(300.0), Some((240.0, 0.9)));
    }

    #[test]
    fn iter_values_matches_values_and_scratch_reuse() {
        let mut tb = TelemetryBuffer::new(0.0, 1000.0);
        let series = [0.5, 0.5, 0.5, 0.6, 0.7, 0.8, 0.5, 0.5];
        for (i, &v) in series.iter().enumerate() {
            tb.record(i as f64 * 2.0, v);
        }
        assert_eq!(tb.iter_values().collect::<Vec<_>>(), tb.values());
        let mut scratch = Vec::new();
        let a = tb.spike_stats(&[2.0, 40.0]);
        let b = tb.spike_stats_with(&[2.0, 40.0], &mut scratch);
        assert_eq!(a, b);
        assert_eq!(scratch.len(), series.len());
        // Second call reuses the scratch capacity.
        let cap = scratch.capacity();
        tb.spike_stats_with(&[2.0], &mut scratch);
        assert_eq!(scratch.capacity(), cap);
    }

    #[test]
    fn utilization_summary() {
        let mut tb = TelemetryBuffer::new(0.0, 1000.0);
        for i in 0..100 {
            tb.record(i as f64, if i == 50 { 0.9 } else { 0.5 });
        }
        let (peak, _p99, mean) = tb.utilization();
        assert!((peak - 0.9).abs() < 1e-12);
        assert!((mean - 0.504).abs() < 1e-9);
    }
}
