//! Power hierarchy: servers → racks → row (PDU breaker) → UPS (Fig 10).
//!
//! Power is provisioned at the row: the breaker budget equals the
//! baseline server count × per-server provisioned power. Oversubscription
//! adds servers *without* raising the budget — the whole point of POLCA.

use crate::power::server::ServerPowerModel;

/// Priority class of the workload a server hosts (§5.B).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Priority {
    /// Cappable first (Algorithm 1's T1/T2 first line of defense).
    Low,
    /// Capped only after LP capping proves insufficient at T2.
    High,
}

/// What a server slot is running: an inference service or a slice of a
/// synchronized training job (the §2.4/§7 mixed-row axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum JobKind {
    /// Interactive inference serving (the paper's Table-4 services).
    #[default]
    Inference,
    /// A synchronized training job: iteration-structured power with
    /// cross-server coordination (§2.4).
    Training,
}

impl JobKind {
    /// The priority class this job kind is pinned to, if any. Training
    /// jobs are always low-priority cappable (§7: capping costs them
    /// only iteration time, never an interactive SLO), so the policy
    /// engine may throttle them on every T1 crossing.
    pub fn fixed_priority(self) -> Option<Priority> {
        match self {
            JobKind::Inference => None,
            JobKind::Training => Some(Priority::Low),
        }
    }
}

/// A server slot in the row.
#[derive(Debug, Clone)]
pub struct Server {
    /// Slot index within the row (stable across the run).
    pub id: usize,
    /// Rack index ([`Row::servers_per_rack`] slots per rack).
    pub rack: usize,
    /// Priority class the power policy caps by.
    pub priority: Priority,
    /// What this slot runs (inference service vs training-job slice).
    pub job: JobKind,
    /// Catalog index of the model this server is dedicated to.
    pub model_idx: usize,
    /// Workload spec index (Table 4 row).
    pub workload_idx: usize,
}

/// A row of racks behind one PDU breaker.
#[derive(Debug, Clone)]
pub struct Row {
    /// Every deployed server slot, in id order.
    pub servers: Vec<Server>,
    /// Rack granularity (10 DGX-class servers per rack).
    pub servers_per_rack: usize,
    /// Shared per-server power model (one SKU per row).
    pub power_model: ServerPowerModel,
    /// Breaker budget in watts (fixed at provisioning time).
    pub budget_w: f64,
    /// UPS failure-tolerance deadline at worst-case load (§4.E: 10 s).
    pub ups_deadline_s: f64,
}

impl Row {
    /// Provision a row for `baseline_servers`, then deploy
    /// `deployed_servers` into it (deployed > baseline = oversubscribed).
    pub fn provision(
        baseline_servers: usize,
        deployed_servers: usize,
        power_model: ServerPowerModel,
    ) -> Row {
        let budget_w = baseline_servers as f64 * power_model.provisioned_w();
        let servers_per_rack = 10;
        let servers = (0..deployed_servers)
            .map(|id| Server {
                id,
                rack: id / servers_per_rack,
                priority: Priority::Low, // assigned later by the allocator
                job: JobKind::Inference,
                model_idx: 0,
                workload_idx: 0,
            })
            .collect();
        Row { servers, servers_per_rack, power_model, budget_w, ups_deadline_s: 10.0 }
    }

    /// Number of racks the deployed servers occupy.
    pub fn num_racks(&self) -> usize {
        if self.servers.is_empty() {
            0
        } else {
            self.servers.last().unwrap().rack + 1
        }
    }

    /// Oversubscription ratio: deployed provisioned power / budget.
    pub fn oversubscription(&self) -> f64 {
        self.servers.len() as f64 * self.power_model.provisioned_w() / self.budget_w
    }

    /// Normalize a wattage to the row budget (the policy's input unit).
    pub fn normalized(&self, watts: f64) -> f64 {
        watts / self.budget_w
    }

    /// Low-priority servers (the T1 capping set).
    pub fn lp_servers(&self) -> impl Iterator<Item = &Server> {
        self.servers.iter().filter(|s| s.priority == Priority::Low)
    }

    /// High-priority servers (capped only above T2).
    pub fn hp_servers(&self) -> impl Iterator<Item = &Server> {
        self.servers.iter().filter(|s| s.priority == Priority::High)
    }

    /// Servers running training-job slices (the §7 colocation set).
    pub fn training_servers(&self) -> impl Iterator<Item = &Server> {
        self.servers.iter().filter(|s| s.job == JobKind::Training)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_fixed_by_baseline() {
        let m = ServerPowerModel::default();
        let per = m.provisioned_w();
        let row = Row::provision(40, 52, m);
        assert!((row.budget_w - 40.0 * per).abs() < 1e-6);
        assert_eq!(row.servers.len(), 52);
        assert!((row.oversubscription() - 1.3).abs() < 1e-9);
    }

    #[test]
    fn racks_assigned_sequentially() {
        let row = Row::provision(40, 25, ServerPowerModel::default());
        assert_eq!(row.num_racks(), 3);
        assert_eq!(row.servers[9].rack, 0);
        assert_eq!(row.servers[10].rack, 1);
    }

    #[test]
    fn normalization() {
        let m = ServerPowerModel::default();
        let row = Row::provision(40, 40, m);
        assert!((row.normalized(row.budget_w) - 1.0).abs() < 1e-12);
        assert!((row.normalized(row.budget_w * 0.79) - 0.79).abs() < 1e-12);
    }

    #[test]
    fn training_is_always_low_priority_cappable() {
        // §7: training never rides the HP class — it is the always-
        // throttleable ballast the mixed-row policy relies on.
        assert_eq!(JobKind::Training.fixed_priority(), Some(Priority::Low));
        assert_eq!(JobKind::Inference.fixed_priority(), None);
        assert_eq!(JobKind::default(), JobKind::Inference);
    }

    #[test]
    fn training_server_filter() {
        let mut row = Row::provision(4, 4, ServerPowerModel::default());
        row.servers[1].job = JobKind::Training;
        row.servers[3].job = JobKind::Training;
        assert_eq!(row.training_servers().count(), 2);
        assert!(row.training_servers().all(|s| s.job == JobKind::Training));
    }

    #[test]
    fn priority_filters() {
        let mut row = Row::provision(4, 4, ServerPowerModel::default());
        row.servers[0].priority = Priority::High;
        row.servers[2].priority = Priority::High;
        assert_eq!(row.hp_servers().count(), 2);
        assert_eq!(row.lp_servers().count(), 2);
    }
}
