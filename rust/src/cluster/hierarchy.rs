//! Power hierarchy: servers → racks → row (PDU breaker) → UPS (Fig 10).
//!
//! Power is provisioned at the row: the breaker budget equals the
//! baseline server count × per-server provisioned power. Oversubscription
//! adds servers *without* raising the budget — the whole point of POLCA.

use crate::power::server::ServerPowerModel;

/// Priority class of the workload a server hosts (§5.B).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Priority {
    Low,
    High,
}

/// A server slot in the row.
#[derive(Debug, Clone)]
pub struct Server {
    pub id: usize,
    pub rack: usize,
    pub priority: Priority,
    /// Catalog index of the model this server is dedicated to.
    pub model_idx: usize,
    /// Workload spec index (Table 4 row).
    pub workload_idx: usize,
}

/// A row of racks behind one PDU breaker.
#[derive(Debug, Clone)]
pub struct Row {
    pub servers: Vec<Server>,
    pub servers_per_rack: usize,
    pub power_model: ServerPowerModel,
    /// Breaker budget in watts (fixed at provisioning time).
    pub budget_w: f64,
    /// UPS failure-tolerance deadline at worst-case load (§4.E: 10 s).
    pub ups_deadline_s: f64,
}

impl Row {
    /// Provision a row for `baseline_servers`, then deploy
    /// `deployed_servers` into it (deployed > baseline = oversubscribed).
    pub fn provision(
        baseline_servers: usize,
        deployed_servers: usize,
        power_model: ServerPowerModel,
    ) -> Row {
        let budget_w = baseline_servers as f64 * power_model.provisioned_w();
        let servers_per_rack = 10;
        let servers = (0..deployed_servers)
            .map(|id| Server {
                id,
                rack: id / servers_per_rack,
                priority: Priority::Low, // assigned later by the allocator
                model_idx: 0,
                workload_idx: 0,
            })
            .collect();
        Row { servers, servers_per_rack, power_model, budget_w, ups_deadline_s: 10.0 }
    }

    pub fn num_racks(&self) -> usize {
        if self.servers.is_empty() {
            0
        } else {
            self.servers.last().unwrap().rack + 1
        }
    }

    /// Oversubscription ratio: deployed provisioned power / budget.
    pub fn oversubscription(&self) -> f64 {
        self.servers.len() as f64 * self.power_model.provisioned_w() / self.budget_w
    }

    /// Normalize a wattage to the row budget (the policy's input unit).
    pub fn normalized(&self, watts: f64) -> f64 {
        watts / self.budget_w
    }

    pub fn lp_servers(&self) -> impl Iterator<Item = &Server> {
        self.servers.iter().filter(|s| s.priority == Priority::Low)
    }

    pub fn hp_servers(&self) -> impl Iterator<Item = &Server> {
        self.servers.iter().filter(|s| s.priority == Priority::High)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_fixed_by_baseline() {
        let m = ServerPowerModel::default();
        let per = m.provisioned_w();
        let row = Row::provision(40, 52, m);
        assert!((row.budget_w - 40.0 * per).abs() < 1e-6);
        assert_eq!(row.servers.len(), 52);
        assert!((row.oversubscription() - 1.3).abs() < 1e-9);
    }

    #[test]
    fn racks_assigned_sequentially() {
        let row = Row::provision(40, 25, ServerPowerModel::default());
        assert_eq!(row.num_racks(), 3);
        assert_eq!(row.servers[9].rack, 0);
        assert_eq!(row.servers[10].rack, 1);
    }

    #[test]
    fn normalization() {
        let m = ServerPowerModel::default();
        let row = Row::provision(40, 40, m);
        assert!((row.normalized(row.budget_w) - 1.0).abs() < 1e-12);
        assert!((row.normalized(row.budget_w * 0.79) - 0.79).abs() < 1e-12);
    }

    #[test]
    fn priority_filters() {
        let mut row = Row::provision(4, 4, ServerPowerModel::default());
        row.servers[0].priority = Priority::High;
        row.servers[2].priority = Priority::High;
        assert_eq!(row.hp_servers().count(), 2);
        assert_eq!(row.lp_servers().count(), 2);
    }
}
