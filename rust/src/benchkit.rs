//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup, timed iterations, and mean/p50/p99/throughput
//! reporting. Used by the `rust/benches/*.rs` targets (declared with
//! `harness = false`) and by the §Perf optimization loop.

use std::time::{Duration, Instant};

use crate::util::stats::Percentiles;

/// Harness parameters for one benchmark.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Untimed warmup budget before measurement starts.
    pub warmup: Duration,
    /// Measurement budget (at least `min_iters` iterations run).
    pub measure: Duration,
    /// Minimum timed iterations regardless of budget.
    pub min_iters: u32,
    /// Hard iteration cap.
    pub max_iters: u32,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            min_iters: 10,
            max_iters: 1_000_000,
        }
    }
}

impl BenchConfig {
    /// Quick config for slow end-to-end benches.
    pub fn slow() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(100),
            measure: Duration::from_secs(4),
            min_iters: 3,
            max_iters: 10_000,
        }
    }
}

/// Timing summary of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Timed iterations executed.
    pub iters: u32,
    /// Mean per-iteration wall time.
    pub mean: Duration,
    /// Median per-iteration wall time.
    pub p50: Duration,
    /// P99 per-iteration wall time.
    pub p99: Duration,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: f64,
}

impl BenchResult {
    /// Items per second at the mean iteration time.
    pub fn throughput(&self) -> f64 {
        self.items_per_iter / self.mean.as_secs_f64()
    }

    /// One-line human-readable report.
    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<42} {:>10} iters  mean {:>12?}  p50 {:>12?}  p99 {:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p99
        );
        if self.items_per_iter > 0.0 {
            s.push_str(&format!("  thrpt {:>12.0}/s", self.throughput()));
        }
        s
    }
}

/// Run `f` under the harness; `items` is the per-iteration work count
/// used for throughput (pass 0.0 to omit).
pub fn bench<F: FnMut()>(name: &str, cfg: &BenchConfig, items: f64, mut f: F) -> BenchResult {
    // Warmup.
    let start = Instant::now();
    while start.elapsed() < cfg.warmup {
        f();
    }
    // Measure.
    let mut samples = Percentiles::new();
    let mut total = Duration::ZERO;
    let mut iters = 0u32;
    while (total < cfg.measure || iters < cfg.min_iters) && iters < cfg.max_iters {
        let t = Instant::now();
        f();
        let dt = t.elapsed();
        samples.push(dt.as_secs_f64());
        total += dt;
        iters += 1;
    }
    let mean = total / iters.max(1);
    BenchResult {
        name: name.to_string(),
        iters,
        mean,
        p50: Duration::from_secs_f64(samples.p50()),
        p99: Duration::from_secs_f64(samples.p99()),
        items_per_iter: items,
    }
}

/// Prevent the optimizer from eliding a value (stable-rust black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(30),
            min_iters: 5,
            max_iters: 100_000,
        };
        let mut acc = 0u64;
        let r = bench("noop-ish", &cfg, 10.0, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.iters >= 5);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.throughput() > 0.0);
        assert!(r.report().contains("noop-ish"));
    }
}
