//! Fault layer: the episode overlay over the control plane.
//!
//! Owns the run's injected [`FaultEvent`] timeline and the degraded
//! state it toggles: meter miscalibration (reported readings lie),
//! feed-loss budget cuts (the effective budget shrinks), cap-ignoring
//! servers (ack without applying; only the brake contains them), and
//! the incident-attribution bookkeeping that scores each episode's
//! time-to-contain at finalize. All of it is inert when the config
//! carries no plan — an empty overlay is bit-identical to no overlay
//! (a tested invariant, see [`crate::faults`]).
//!
//! Telemetry freezes and OOB storms have no state here: their episode
//! toggles degrade the control layer's transport objects directly
//! (`Sim::on_fault_start` / `Sim::on_fault_end`).

use crate::faults::{FaultEvent, FaultKind};
use crate::metrics::IncidentOutcome;
use crate::obs::{EventKind as ObsEvent, Observer};

use super::core::Sim;
use super::SimConfig;

/// Injected episodes plus the degraded-state overlay they control.
pub(crate) struct FaultLayer {
    /// The run's fault episodes, sorted by start time.
    pub(crate) events: Vec<FaultEvent>,
    /// Multiplicative bias on reported (not true) power readings.
    pub(crate) meter_bias: f64,
    /// Effective-budget fraction (feed loss cuts it below 1.0).
    pub(crate) budget_mult: f64,
    /// Servers currently acknowledging-but-ignoring cap commands.
    pub(crate) cap_ignore: Vec<bool>,
    /// Most recently started fault episode (violations attribute to it).
    pub(crate) cur_incident: Option<usize>,
    /// Per-episode: last instant the row was observed over budget.
    pub(crate) incident_last_violation: Vec<Option<f64>>,
}

impl FaultLayer {
    pub(crate) fn new(cfg: &SimConfig, n_servers: usize) -> FaultLayer {
        let events = cfg
            .faults
            .as_ref()
            .map(|p| p.normalized().expect("invalid fault plan"))
            .unwrap_or_default();
        let n_faults = events.len();
        FaultLayer {
            events,
            meter_bias: 1.0,
            budget_mult: 1.0,
            cap_ignore: vec![false; n_servers],
            cur_incident: None,
            incident_last_violation: vec![None; n_faults],
        }
    }
}

impl<'a, O: Observer> Sim<'a, O> {
    /// A fault episode begins: degrade the corresponding control-plane
    /// link. Violations from here on attribute to this incident.
    pub(crate) fn on_fault_start(&mut self, i: usize, now_s: f64) {
        self.faults.cur_incident = Some(i);
        let ev = self.faults.events[i];
        if O::ENABLED {
            self.obs
                .event(now_s, ObsEvent::FaultStart { fault: i as u32, label: ev.kind.label() });
        }
        match ev.kind {
            FaultKind::TelemetryFreeze => self.control.telemetry.freeze(now_s, ev.end_s()),
            FaultKind::OobStorm { loss_prob, latency_mult, jitter_frac } => {
                self.control.oob.set_unreliability(loss_prob, jitter_frac);
                self.control.oob.set_latency_mult(latency_mult);
            }
            FaultKind::CapIgnore { server_frac } => {
                let n = ((server_frac * self.servers.n_servers() as f64).ceil() as usize)
                    .min(self.servers.n_servers());
                for idx in 0..n {
                    self.faults.cap_ignore[idx] = true;
                }
            }
            FaultKind::MeterBias { mult } => self.faults.meter_bias = mult,
            FaultKind::FeedLoss { budget_frac } => {
                // Close the accounting segment under the old budget
                // before the effective budget changes.
                self.settle_energy();
                self.faults.budget_mult = budget_frac.max(1e-6);
            }
        }
    }

    /// A fault episode ends: restore the baseline control plane.
    pub(crate) fn on_fault_end(&mut self, i: usize, now_s: f64) {
        let ev = self.faults.events[i];
        if O::ENABLED {
            self.obs.event(now_s, ObsEvent::FaultEnd { fault: i as u32, label: ev.kind.label() });
        }
        match ev.kind {
            // The freeze window expires by itself inside the buffer.
            FaultKind::TelemetryFreeze => {}
            FaultKind::OobStorm { .. } => {
                self.control
                    .oob
                    .set_unreliability(self.cfg.oob_loss_prob, self.cfg.oob_jitter_frac);
                self.control.oob.set_latency_mult(1.0);
            }
            FaultKind::CapIgnore { .. } => {
                // The wedged firmware recovers and drains its queue:
                // converge every affected server to the last
                // acknowledged cap state of its class.
                for idx in 0..self.servers.n_servers() {
                    if !self.faults.cap_ignore[idx] {
                        continue;
                    }
                    self.faults.cap_ignore[idx] = false;
                    let cap = match self.servers.priority[idx] {
                        crate::cluster::hierarchy::Priority::Low => self.control.acked_lp,
                        crate::cluster::hierarchy::Priority::High => self.control.acked_hp,
                    };
                    self.set_server_cap(idx, cap, now_s);
                }
            }
            FaultKind::MeterBias { .. } => self.faults.meter_bias = 1.0,
            FaultKind::FeedLoss { .. } => {
                self.settle_energy();
                self.faults.budget_mult = 1.0;
            }
        }
    }

    /// Per-incident containment outcomes, written at finalize.
    pub(crate) fn finalize_incidents(&mut self) {
        let scaled_w = self.cfg.power_scale * self.servers.row_power_w;
        let still_violating = scaled_w > self.servers.row.budget_w * self.faults.budget_mult;
        for (i, f) in self.faults.events.iter().enumerate() {
            let time_to_contain_s = match self.faults.incident_last_violation[i] {
                None => 0.0,
                Some(_) if still_violating && self.faults.cur_incident == Some(i) => f64::INFINITY,
                Some(last) => (last - f.start_s).max(0.0),
            };
            self.acct.report.resilience.incidents.push(IncidentOutcome {
                label: f.kind.label().to_string(),
                start_s: f.start_s,
                end_s: f.end_s(),
                time_to_contain_s,
            });
        }
    }
}
