//! The row-level cluster simulator — the paper's §6 evaluation vehicle.
//!
//! A discrete-event simulation of one datacenter row: `deployed` DGX
//! servers behind a PDU breaker provisioned for `baseline` servers,
//! each dedicated to a Table-4 service on BLOOM-176B (§6.1), with:
//!
//!   * non-homogeneous Poisson arrivals (diurnal, §3.2),
//!   * a one-request buffer per server (§6.3 queueing model),
//!   * per-request two-phase execution (prompt/token) whose speed follows
//!     the current frequency cap ([`crate::perfmodel::RequestExec`]),
//!   * instantaneous row power aggregated from per-server phase power,
//!   * PDU telemetry with 2 s delay driving the policy engine,
//!   * OOB cap commands with 40 s latency, powerbrake with 5 s (Table 1),
//!   * the powerbrake backstop when real power exceeds the breaker.
//!
//! Power calibration: the analytic single-request server model
//! understates the sustained draw of production serving (continuous
//! batching, co-located services), so a scalar `power_scale` is fitted
//! once so the *base* row (no oversubscription, no capping) peaks at the
//! published Table-2 inference utilization (79%) — the same
//! trace-replication step the paper performs in §6.1.
//!
//! # Mixed-workload rows (§2.4 / §7)
//!
//! A [`MixedRowConfig`] colocates synchronized training jobs with the
//! inference services: the last `training_fraction` of the deployed
//! servers run the [`TrainingProfile`] waveform instead of serving
//! requests. Training jobs advance on the same event queue — one event
//! per waveform phase per *job*, so every server of a job switches
//! phase at the same instant and the row-level swings coordinate
//! exactly as the paper observes. Training is always low-priority
//! cappable ([`crate::cluster::hierarchy::JobKind::fixed_priority`]);
//! frequency caps change training power immediately and stretch the
//! *next* iteration's compute-bound fraction (gradient-sync barriers
//! quantize the timing effect at iteration granularity), reported as
//! iteration-time inflation ([`crate::metrics::TrainingMetrics`])
//! rather than request latency. The `power_scale` calibration is an
//! inference-serving artifact, so training wattage is kept absolute by
//! dividing it out per server (the row aggregate multiplies it back).
//!
//! # Fault injection (§6/§7 robustness)
//!
//! A [`crate::faults::FaultPlan`] on [`SimConfig::faults`] interleaves
//! control-plane fault episodes with the workload: telemetry dropouts
//! (the manager reads stale), OOB loss bursts and latency storms,
//! cap-ignoring servers (ack without applying — only the brake path
//! contains them), meter miscalibration, and feed-loss budget cuts.
//! Ground-truth budget-violation accounting
//! ([`crate::metrics::ResilienceMetrics`]) is settled exactly on every
//! power change, independent of what the possibly-lying meter reports;
//! docs/RELIABILITY.md is the runbook mapping each fault to its knob,
//! detection metric, and expected policy response.

use crate::characterize::catalog::{self, ModelSpec};
use crate::cluster::hierarchy::{JobKind, Priority, Row};
use crate::cluster::oob::{OobChannel, OobCommand};
use crate::cluster::telemetry::TelemetryBuffer;
use crate::config::ExperimentConfig;
use crate::faults::{FaultEvent, FaultKind, FaultPlan};
use crate::metrics::{IncidentOutcome, RunReport};
use crate::perfmodel::{ExecPhase, RequestExec};
use crate::policy::engine::{Action, PolicyEngine, PolicyKind};
use crate::power::gpu::{CapMode, Phase};
use crate::power::training::{TrainingPowerModel, TrainingProfile};
use crate::sim::{secs, to_secs, EventQueue, SimTime};
use crate::util::rng::Rng;
use crate::workload::arrivals::ArrivalProcess;
use crate::workload::spec::{assign_servers, sample_request, WorkloadSpec};

/// Mixed-row parameters: colocate synchronized training jobs with the
/// inference services (§2.4 contrast, §7 mixing direction).
#[derive(Debug, Clone)]
pub struct MixedRowConfig {
    /// Fraction of the *deployed* servers running training (0.0 = pure
    /// inference, 1.0 = pure training row). The training servers are
    /// carved deterministically off the tail of the row so every
    /// fraction shares one inference workload realization (see
    /// [`crate::workload::spec::mark_training`]).
    pub training_fraction: f64,
    /// Servers per synchronized job; 0 means one job spans every
    /// training server (the paper's large-job worst case, maximally
    /// coordinated row swings).
    pub servers_per_job: usize,
    /// Offset between consecutive jobs' start times, seconds. Staggered
    /// jobs de-align their synchronization troughs, shrinking the
    /// row-level swing — the §7 lever an operator controls.
    pub job_stagger_s: f64,
    /// Iteration waveform every job runs.
    pub profile: TrainingProfile,
}

impl Default for MixedRowConfig {
    fn default() -> Self {
        MixedRowConfig {
            training_fraction: 0.0,
            servers_per_job: 0,
            job_stagger_s: 0.0,
            profile: TrainingProfile::large_llm(),
        }
    }
}

/// Simulation parameters for one run.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Row/policy/SLO parameters (paper Tables 1/3/5) and the seed.
    pub exp: ExperimentConfig,
    /// Which power-management policy drives the row.
    pub policy_kind: PolicyKind,
    /// Servers actually deployed (baseline = exp.row.num_servers;
    /// more = oversubscribed).
    pub deployed_servers: usize,
    /// Simulated horizon in weeks (fractions allowed for quick runs).
    pub weeks: f64,
    /// Catalog model every server is dedicated to (§6.1: BLOOM-176B).
    pub model_name: String,
    /// Override the global LP share (Fig 15b sweep).
    pub lp_fraction_override: Option<f64>,
    /// Row-power calibration factor (see module docs / [`calibrate`]).
    pub power_scale: f64,
    /// Multiplier on per-workload power (Fig 17 "+5%" robustness study).
    pub workload_power_mult: f64,
    /// Target server busy fraction at the diurnal peak (drives arrivals).
    pub peak_utilization: f64,
    /// Sample the power series every this many seconds (0 = off).
    pub series_sample_s: f64,
    /// OOB command-loss probability (0.0 = the paper's reliable channel).
    pub oob_loss_prob: f64,
    /// OOB apply-latency jitter fraction (uniform ±).
    pub oob_jitter_frac: f64,
    /// When false, the power manager is disconnected entirely (no caps,
    /// no brake): the unthrottled counterfactual used as the latency
    /// baseline for impact measurement (see [`crate::metrics`]).
    pub protection: bool,
    /// Override the server power model (heterogeneous SKUs — see
    /// [`crate::fleet::sku`]). `None` derives the DGX-A100 default from
    /// the catalog calibration, as the paper does.
    pub server_model: Option<crate::power::server::ServerPowerModel>,
    /// Throughput multiplier applied to the model's latency anchors
    /// (prompt/decode tokens-per-second). Faster SKUs (H100-class) serve
    /// the same model at a multiple of the A100 anchors.
    pub perf_mult: f64,
    /// Diurnal phase offset (s) applied to every arrival stream: this
    /// row serves a region whose traffic peaks earlier/later than site
    /// time (fleet layer staggers cluster peaks with this).
    pub diurnal_phase_s: f64,
    /// Mixed-row configuration (`None` = the paper's inference-only
    /// row; `Some` with `training_fraction: 0.0` is bit-identical to
    /// `None` — a tested invariant).
    pub mixed: Option<MixedRowConfig>,
    /// Fault-injection timeline (`None` = the paper's well-behaved
    /// control plane; `Some` with an empty plan is bit-identical to
    /// `None` — a tested invariant, see [`crate::faults`]).
    pub faults: Option<FaultPlan>,
    /// Enable the policy engine's containment escalation: brake when the
    /// full cap set has visibly failed to pull the reading under T2 for
    /// this many seconds (`None` = paper behavior; see
    /// [`crate::policy::engine::PolicyEngine::escalate_to_brake_after_s`]).
    pub brake_escalation_s: Option<f64>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            exp: ExperimentConfig::default(),
            policy_kind: PolicyKind::Polca,
            deployed_servers: 40,
            weeks: 1.0,
            model_name: "BLOOM-176B".to_string(),
            lp_fraction_override: None,
            power_scale: DEFAULT_POWER_SCALE,
            workload_power_mult: 1.0,
            peak_utilization: 0.85,
            series_sample_s: 0.0,
            oob_loss_prob: 0.0,
            oob_jitter_frac: 0.0,
            protection: true,
            server_model: None,
            perf_mult: 1.0,
            diurnal_phase_s: 0.0,
            mixed: None,
            faults: None,
            brake_escalation_s: None,
        }
    }
}

impl SimConfig {
    /// The unthrottled counterfactual of this configuration: identical
    /// workload realization (same seed), power manager disconnected.
    pub fn baseline(&self) -> SimConfig {
        let mut b = self.clone();
        b.protection = false;
        b.policy_kind = PolicyKind::NoCap;
        b.series_sample_s = 0.0;
        b
    }
}

/// Run a policy config and its paired baseline; return (report, impact).
pub fn run_with_impact(cfg: &SimConfig) -> (RunReport, crate::metrics::ImpactSummary) {
    let mut report = run(cfg);
    let mut base = run(&cfg.baseline());
    let impact = report.impact_vs(&mut base);
    (report, impact)
}

/// Fitted once via [`calibrate`] with the default config; pins the base
/// row's diurnal peak at the Table-2 inference utilization (≈0.79).
pub const DEFAULT_POWER_SCALE: f64 = 1.74;

/// The row-size-appropriate power calibration: small rows multiplex
/// fewer prompt spikes, so their relative variance is higher and the
/// fitted scale is smaller (see the module docs; shared by the fleet
/// layer and the fault matrix so every surface calibrates identically).
pub fn power_scale_for_row(baseline_servers: usize) -> f64 {
    if baseline_servers >= 40 {
        DEFAULT_POWER_SCALE
    } else if baseline_servers >= 16 {
        1.45
    } else {
        1.35
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Ev {
    /// A request arrives at a server.
    Arrival { server: u32 },
    /// The current phase of the server's in-flight request completes
    /// (valid only if `gen` matches the server's generation counter).
    PhaseEnd { server: u32, gen: u32 },
    /// PDU sample + policy tick.
    Telemetry,
    /// An OOB command becomes effective.
    OobApply,
    /// A training job begins its first iteration (staggered job starts).
    TrainStart { job: u32 },
    /// A training job's current waveform phase ends (valid only if `gen`
    /// matches the job's generation counter).
    TrainPhase { job: u32, gen: u32 },
    /// Record a point of the downsampled power series.
    SampleSeries,
    /// A scheduled fault episode begins (index into the run's fault plan).
    FaultStart { fault: u32 },
    /// A scheduled fault episode ends (degraded state is restored).
    FaultEnd { fault: u32 },
    End,
}

#[derive(Debug, Clone)]
struct InFlight {
    exec: RequestExec,
    arrived_s: f64,
    priority: Priority,
}

#[derive(Debug, Clone)]
struct QueuedReq {
    input: f64,
    output: f64,
    arrived_s: f64,
}

struct ServerState {
    priority: Priority,
    kind: JobKind,
    workload_idx: usize,
    freq_cap_mhz: Option<f64>,
    current: Option<InFlight>,
    queued: Option<QueuedReq>,
    arrivals: ArrivalProcess,
    rng: Rng,
    /// Generation counter invalidating stale PhaseEnd events.
    gen: u32,
    /// Time work was last advanced (for mid-flight cap changes).
    last_advance_s: f64,
    /// Current power draw in watts (cached for incremental row sum).
    power_w: f64,
    /// Training servers only: the nominal GPU power fraction of the
    /// job's current waveform phase (idle before the job starts).
    train_level: f64,
}

/// One synchronized training job: every member server switches waveform
/// phase on the same event, so row-level swings coordinate (§2.4).
struct TrainJob {
    /// Indices into `Sim::servers`.
    servers: Vec<usize>,
    model: TrainingPowerModel,
    /// Job start time (staggered per job).
    start_s: f64,
    /// Generation counter invalidating stale TrainPhase events.
    gen: u32,
    /// Current phase index into `TrainingProfile::phase_levels`.
    phase_idx: usize,
    iter_started_s: f64,
    /// Wall time of the in-flight iteration (stretched by the cap that
    /// was active when it started).
    iter_wall_s: f64,
}

/// Run one simulation; returns the report.
pub fn run(cfg: &SimConfig) -> RunReport {
    Sim::new(cfg).run()
}

/// Whether a slow-path command addresses the given priority class.
fn targets(cmd: &OobCommand, p: Priority) -> bool {
    match cmd {
        OobCommand::FreqCap { target, .. } | OobCommand::Uncap { target } => *target == p,
        OobCommand::PowerBrake | OobCommand::ReleaseBrake => false,
    }
}

struct Sim<'a> {
    cfg: &'a SimConfig,
    model: ModelSpec,
    specs: Vec<WorkloadSpec>,
    row: Row,
    servers: Vec<ServerState>,
    train_jobs: Vec<TrainJob>,
    queue: EventQueue<Ev>,
    policy: PolicyEngine,
    oob: OobChannel,
    telemetry: TelemetryBuffer,
    braked: bool,
    brake_engaged_at: f64,
    row_power_w: f64,
    /// Energy accumulator for window-averaged PDU readings: real PDU
    /// meters report power averaged over the sampling period, not
    /// instantaneous draw — sub-second prompt-spike alignments are
    /// smoothed by the meter (and are harmless physically: the UPS
    /// tolerates 133% load for 10 s, §4.E). Table 2's spike statistics
    /// are computed on these averaged readings.
    energy_acc_ws: f64,
    last_power_change_s: f64,
    last_telemetry_s: f64,
    /// Simulation "now" (set by the event loop before each handler), so
    /// power changes can settle the energy accumulator.
    now_s: f64,
    report: RunReport,
    horizon: SimTime,
    // -- fault-injection state (all inert when `cfg.faults` is empty) --
    /// The run's fault episodes, sorted by start time.
    fault_events: Vec<FaultEvent>,
    /// Multiplicative bias on reported (not true) power readings.
    meter_bias: f64,
    /// Effective-budget fraction (feed loss cuts it below 1.0).
    budget_mult: f64,
    /// Servers currently acknowledging-but-ignoring cap commands.
    cap_ignore: Vec<bool>,
    /// Last slow-path cap state *acknowledged* per priority class (what
    /// the rack manager believes is applied; cap-ignoring servers ack
    /// without applying, so reconciliation cannot see them).
    acked_lp: Option<f64>,
    acked_hp: Option<f64>,
    /// Last attempt times per class, for the re-issue timeout.
    lp_last_issue_s: f64,
    hp_last_issue_s: f64,
    /// Most recently started fault episode (violations attribute to it).
    cur_incident: Option<usize>,
    /// Per-episode: last instant the row was observed over budget.
    incident_last_violation: Vec<Option<f64>>,
}

impl<'a> Sim<'a> {
    fn new(cfg: &'a SimConfig) -> Self {
        let mut model = catalog::find(&cfg.model_name).expect("model not in catalog");
        // Fig 17 robustness knob: workloads draw more than profiled.
        if cfg.workload_power_mult != 1.0 {
            model.power.prompt_peak_at_256 *= cfg.workload_power_mult;
            model.power.prompt_peak_at_8192 *= cfg.workload_power_mult;
            model.power.token_mean_at_b1 *= cfg.workload_power_mult;
            model.power.token_mean_at_b16 *= cfg.workload_power_mult;
        }
        // Fleet SKU knob: faster silicon shifts the latency anchors.
        if cfg.perf_mult != 1.0 {
            model.prompt_tokens_per_s *= cfg.perf_mult;
            model.decode_tokens_per_s *= cfg.perf_mult;
        }
        let mut power_model = cfg.server_model.clone().unwrap_or_else(|| {
            crate::power::server::ServerPowerModel { calib: model.power, ..Default::default() }
        });
        // An explicit server model carries its own calibration, so the
        // Fig-17 robustness multiplier must be applied to it directly
        // (the scaling above only touched the catalog-derived default).
        if cfg.server_model.is_some() && cfg.workload_power_mult != 1.0 {
            let c = &mut power_model.calib;
            c.prompt_peak_at_256 *= cfg.workload_power_mult;
            c.prompt_peak_at_8192 *= cfg.workload_power_mult;
            c.token_mean_at_b1 *= cfg.workload_power_mult;
            c.token_mean_at_b16 *= cfg.workload_power_mult;
        }
        let mut root_rng = Rng::new(cfg.exp.seed ^ 0x9E3779B97F4A7C15);
        let mut row = Row::provision(cfg.exp.row.num_servers, cfg.deployed_servers, power_model);
        let specs = crate::workload::spec::table4();
        assign_servers(&mut row, &specs, 0, cfg.lp_fraction_override, &mut root_rng);
        // Mixed rows: carve training servers off the tail AFTER the
        // inference assignment, so every training fraction consumes the
        // identical random stream (0% is bit-identical to `mixed: None`,
        // and sweeps interpolate on one fixed workload realization).
        let train_count = cfg
            .mixed
            .as_ref()
            .map(|m| {
                ((m.training_fraction * row.servers.len() as f64).round() as usize)
                    .min(row.servers.len())
            })
            .unwrap_or(0);
        if train_count > 0 {
            crate::workload::spec::mark_training(&mut row, train_count);
        }

        // Per-workload peak arrival rate from the target utilization:
        // rate = utilization / E[nominal service time of that workload].
        let mut mean_service: Vec<f64> = Vec::new();
        let mut est_rng = root_rng.fork(77);
        for spec in &specs {
            let mut acc = 0.0;
            let n = 400;
            for _ in 0..n {
                let (i, o) = sample_request(spec, &mut est_rng);
                acc += model.request_latency_s(i, o, 1.0, 1.0);
            }
            mean_service.push(acc / n as f64);
        }

        let idle_frac = row.power_model.calib.idle_frac;
        let servers = row
            .servers
            .iter()
            .map(|s| {
                let rate = cfg.peak_utilization / mean_service[s.workload_idx];
                ServerState {
                    priority: s.priority,
                    kind: s.job,
                    workload_idx: s.workload_idx,
                    freq_cap_mhz: None,
                    current: None,
                    queued: None,
                    arrivals: ArrivalProcess::new(rate, root_rng.fork(1000 + s.id as u64))
                        .with_phase(cfg.diurnal_phase_s),
                    rng: root_rng.fork(2000 + s.id as u64),
                    gen: 0,
                    last_advance_s: 0.0,
                    power_w: 0.0,
                    train_level: idle_frac,
                }
            })
            .collect();

        // One synchronized job per `servers_per_job` chunk of the
        // training tail; 0 = a single row-spanning job (§2.4's
        // large-job worst case).
        let mut train_jobs = Vec::new();
        if let Some(m) = &cfg.mixed {
            let train_idxs: Vec<usize> = row
                .servers
                .iter()
                .enumerate()
                .filter(|(_, s)| s.job == JobKind::Training)
                .map(|(i, _)| i)
                .collect();
            if !train_idxs.is_empty() {
                let per =
                    if m.servers_per_job == 0 { train_idxs.len() } else { m.servers_per_job };
                for (j, chunk) in train_idxs.chunks(per.max(1)).enumerate() {
                    train_jobs.push(TrainJob {
                        servers: chunk.to_vec(),
                        model: TrainingPowerModel::with_calib(m.profile, row.power_model.calib),
                        start_s: j as f64 * m.job_stagger_s.max(0.0),
                        gen: 0,
                        phase_idx: 0,
                        iter_started_s: 0.0,
                        iter_wall_s: m.profile.iter_time_s,
                    });
                }
            }
        }
        let mut report = RunReport::default();
        if !train_jobs.is_empty() {
            report.train.nominal_iter_s =
                cfg.mixed.as_ref().map(|m| m.profile.iter_time_s).unwrap_or(0.0);
        }

        let mut policy = PolicyEngine::new(cfg.policy_kind, cfg.exp.policy.clone());
        policy.escalate_to_brake_after_s = cfg.brake_escalation_s;
        let fault_events = cfg
            .faults
            .as_ref()
            .map(|p| p.normalized().expect("invalid fault plan"))
            .unwrap_or_default();
        let oob = OobChannel::new(
            cfg.exp.row.oob_latency_s,
            cfg.exp.row.power_brake_latency_s,
            cfg.exp.seed ^ 0xBEEF,
        )
        .with_unreliability(cfg.oob_loss_prob, cfg.oob_jitter_frac);
        let horizon = secs(cfg.weeks * 7.0 * 86_400.0);
        let telemetry = TelemetryBuffer::new(
            cfg.exp.row.telemetry_delay_s,
            cfg.weeks * 7.0 * 86_400.0 + 1.0, // retain everything for Table 2 stats
        );

        let n_servers = servers.len();
        let n_faults = fault_events.len();
        Sim {
            cfg,
            model,
            specs,
            row,
            servers,
            train_jobs,
            queue: EventQueue::with_capacity(1024),
            policy,
            oob,
            telemetry,
            braked: false,
            brake_engaged_at: 0.0,
            row_power_w: 0.0,
            energy_acc_ws: 0.0,
            last_power_change_s: 0.0,
            last_telemetry_s: 0.0,
            now_s: 0.0,
            report,
            horizon,
            fault_events,
            meter_bias: 1.0,
            budget_mult: 1.0,
            cap_ignore: vec![false; n_servers],
            acked_lp: None,
            acked_hp: None,
            lp_last_issue_s: f64::NEG_INFINITY,
            hp_last_issue_s: f64::NEG_INFINITY,
            cur_incident: None,
            incident_last_violation: vec![None; n_faults],
        }
    }

    // ---- power bookkeeping ------------------------------------------------

    fn freq_ratio(&self, idx: usize) -> f64 {
        if self.braked {
            return self.cfg.exp.policy.brake_freq_mhz / self.cfg.exp.policy.max_freq_mhz;
        }
        match self.servers[idx].freq_cap_mhz {
            Some(mhz) => mhz / self.cfg.exp.policy.max_freq_mhz,
            None => 1.0,
        }
    }

    fn cap_mode(&self, idx: usize) -> CapMode {
        if self.braked {
            CapMode::FreqCap { mhz: self.cfg.exp.policy.brake_freq_mhz }
        } else {
            match self.servers[idx].freq_cap_mhz {
                Some(mhz) => CapMode::FreqCap { mhz },
                None => CapMode::None,
            }
        }
    }

    fn server_phase(&self, idx: usize) -> Phase {
        match &self.servers[idx].current {
            None => Phase::Idle,
            Some(inf) => match inf.exec.phase() {
                ExecPhase::Prompt => Phase::Prompt { total_input: inf.exec.input * inf.exec.batch },
                ExecPhase::Token | ExecPhase::Done => Phase::Token { batch: inf.exec.batch },
            },
        }
    }

    /// Settle the energy accumulator up to the current event time (must
    /// run before any change to `row_power_w` or to the effective
    /// budget). Power is constant over the settled segment, so the
    /// ground-truth violation accounting here is exact, not sampled —
    /// and independent of what the (possibly miscalibrated) meter says.
    fn settle_energy(&mut self) {
        let dt = (self.now_s - self.last_power_change_s).max(0.0);
        if dt > 0.0 {
            self.energy_acc_ws += self.row_power_w * dt;
            let scaled_w = self.cfg.power_scale * self.row_power_w;
            let budget_eff_w = self.row.budget_w * self.budget_mult;
            let r = &mut self.report.resilience;
            r.true_peak_norm = r.true_peak_norm.max(scaled_w / budget_eff_w);
            if scaled_w > budget_eff_w {
                r.violation_s += dt;
                r.overshoot_ws += (scaled_w - budget_eff_w) * dt;
                r.peak_overshoot_w = r.peak_overshoot_w.max(scaled_w - budget_eff_w);
                if let Some(i) = self.cur_incident {
                    self.incident_last_violation[i] = Some(self.now_s);
                }
            } else if let Some(i) = self.cur_incident {
                // The row is back under budget: once the incident's
                // episode is over, stop attributing to it — later
                // violations (e.g. natural diurnal excursions hours
                // after the fault) are not this incident's tail. A
                // violation straddling the episode end keeps
                // attributing until it is actually contained.
                if self.now_s >= self.fault_events[i].end_s() {
                    self.cur_incident = None;
                }
            }
        }
        self.last_power_change_s = self.now_s;
    }

    /// Training server wall power in watts: the job's current waveform
    /// level under this server's cap, through the shared server model.
    fn training_server_w(&self, idx: usize) -> f64 {
        let cap = self.cap_mode(idx);
        let nominal = self.servers[idx].train_level;
        let frac = self.row.power_model.calib.capped_level(nominal, cap);
        self.row.power_model.training_power_w(frac)
    }

    /// Recompute one server's power and update the row aggregate.
    fn refresh_power(&mut self, idx: usize) {
        self.settle_energy();
        let w = match self.servers[idx].kind {
            JobKind::Inference => {
                let phase = self.server_phase(idx);
                let cap = self.cap_mode(idx);
                self.row.power_model.server_power_w(phase, cap, false)
            }
            // Training power is absolute (the §2.4 waveform drives the
            // GPUs directly); `power_scale` is an inference-serving
            // calibration, so divide it out here — the row aggregate
            // multiplies it back in `normalized_row_power`.
            JobKind::Training => self.training_server_w(idx) / self.cfg.power_scale,
        };
        let s = &mut self.servers[idx];
        self.row_power_w += w - s.power_w;
        s.power_w = w;
    }

    /// Window-averaged normalized power since the last telemetry sample —
    /// what the PDU meter actually *reports*: scaled by any active meter
    /// miscalibration and normalized against the effective budget (a
    /// feed loss raises the manager-visible fraction because the manager
    /// knows the budget shrank).
    fn averaged_row_power(&mut self) -> f64 {
        self.settle_energy();
        let window = (self.now_s - self.last_telemetry_s).max(1e-9);
        let avg_w = self.energy_acc_ws / window;
        self.energy_acc_ws = 0.0;
        self.last_telemetry_s = self.now_s;
        self.meter_bias * self.cfg.power_scale * avg_w / (self.row.budget_w * self.budget_mult)
    }

    fn normalized_row_power(&self) -> f64 {
        self.cfg.power_scale * self.row_power_w / self.row.budget_w
    }

    // ---- request lifecycle --------------------------------------------

    fn start_request(&mut self, idx: usize, input: f64, output: f64, arrived_s: f64, now_s: f64) {
        let exec = RequestExec::new(&self.model, input, output, 1.0);
        self.servers[idx].current = Some(InFlight {
            exec,
            arrived_s,
            priority: self.servers[idx].priority,
        });
        self.servers[idx].last_advance_s = now_s;
        self.servers[idx].gen = self.servers[idx].gen.wrapping_add(1);
        self.refresh_power(idx);
        self.schedule_phase_end(idx, now_s);
    }

    fn schedule_phase_end(&mut self, idx: usize, now_s: f64) {
        let ratio = self.freq_ratio(idx);
        let wall = match &self.servers[idx].current {
            Some(inf) if inf.exec.phase() != ExecPhase::Done => {
                inf.exec.wall_to_phase_end(&self.model, ratio)
            }
            _ => return,
        };
        let gen = self.servers[idx].gen;
        // +1 µs guard: `secs` rounds to integer microseconds, which can
        // land *before* the true phase end and loop the event at the same
        // timestamp. Overshooting by a microsecond guarantees progress.
        self.queue.schedule_at(secs(now_s + wall) + 1, Ev::PhaseEnd { server: idx as u32, gen });
    }

    /// Advance the in-flight request's work to `now` at the *current*
    /// ratio (call BEFORE changing the ratio).
    fn advance_work(&mut self, idx: usize, now_s: f64) {
        let ratio = self.freq_ratio(idx);
        let last = self.servers[idx].last_advance_s;
        if let Some(inf) = &mut self.servers[idx].current {
            let dt = (now_s - last).max(0.0);
            if dt > 0.0 {
                inf.exec.advance(&self.model, ratio, dt);
            }
        }
        self.servers[idx].last_advance_s = now_s;
    }

    /// Apply a frequency change to one server (work-conserving).
    fn set_server_cap(&mut self, idx: usize, cap: Option<f64>, now_s: f64) {
        if self.servers[idx].freq_cap_mhz == cap {
            return;
        }
        self.advance_work(idx, now_s);
        self.servers[idx].freq_cap_mhz = cap;
        self.servers[idx].gen = self.servers[idx].gen.wrapping_add(1);
        self.refresh_power(idx);
        self.schedule_phase_end(idx, now_s);
    }

    fn set_brake(&mut self, on: bool, now_s: f64) {
        if self.braked == on {
            return;
        }
        // Advance all running work at the old ratios first.
        for idx in 0..self.servers.len() {
            self.advance_work(idx, now_s);
        }
        self.braked = on;
        if on {
            self.brake_engaged_at = now_s;
        } else {
            self.report.brake_time_s += now_s - self.brake_engaged_at;
        }
        for idx in 0..self.servers.len() {
            self.servers[idx].gen = self.servers[idx].gen.wrapping_add(1);
            self.refresh_power(idx);
            self.schedule_phase_end(idx, now_s);
        }
    }

    // ---- event handlers -------------------------------------------------

    fn on_arrival(&mut self, idx: usize, now_s: f64) {
        // Schedule the next arrival for this server.
        let next = self.servers[idx].arrivals.next_after(now_s);
        self.queue.schedule_at(secs(next), Ev::Arrival { server: idx as u32 });

        let spec = &self.specs[self.servers[idx].workload_idx];
        let (input, output) = sample_request(spec, &mut self.servers[idx].rng);
        if self.servers[idx].current.is_none() {
            self.start_request(idx, input, output, now_s, now_s);
        } else if self.servers[idx].queued.is_none() {
            self.servers[idx].queued = Some(QueuedReq { input, output, arrived_s: now_s });
        } else {
            // Buffer full: request is rejected (load-balancer would retry
            // elsewhere; within this row it counts against throughput).
            let pri = self.servers[idx].priority;
            self.report.by_priority(pri).dropped += 1;
        }
    }

    fn on_phase_end(&mut self, idx: usize, gen: u32, now_s: f64) {
        if self.servers[idx].gen != gen {
            return; // stale (frequency changed; a new event is scheduled)
        }
        self.advance_work(idx, now_s);
        let phase = self.servers[idx].current.as_ref().map(|i| i.exec.phase());
        match phase {
            Some(ExecPhase::Token) => {
                // Prompt just finished; token phase begins.
                self.servers[idx].gen = self.servers[idx].gen.wrapping_add(1);
                self.refresh_power(idx);
                self.schedule_phase_end(idx, now_s);
            }
            Some(ExecPhase::Done) => {
                let inf = self.servers[idx].current.take().unwrap();
                let actual = now_s - inf.arrived_s;
                self.report.by_priority(inf.priority).record(
                    actual,
                    inf.exec.nominal_latency,
                    inf.exec.output,
                );
                self.servers[idx].gen = self.servers[idx].gen.wrapping_add(1);
                // Pull the buffered request, if any.
                if let Some(q) = self.servers[idx].queued.take() {
                    self.start_request(idx, q.input, q.output, q.arrived_s, now_s);
                } else {
                    self.refresh_power(idx);
                }
            }
            Some(ExecPhase::Prompt) | None => {
                // Numerical residue: reschedule to finish the phase.
                self.refresh_power(idx);
                self.schedule_phase_end(idx, now_s);
            }
        }
    }

    fn on_telemetry(&mut self, now_s: f64) {
        self.queue.schedule_in(secs(self.cfg.exp.row.telemetry_period_s), Ev::Telemetry);
        let p = self.averaged_row_power();
        if now_s == 0.0 {
            return; // no averaging window yet — first real sample comes next tick
        }
        self.telemetry.record(now_s, p);
        if !self.cfg.protection {
            return;
        }
        let Some((_, visible)) = self.telemetry.visible_at(now_s) else {
            return;
        };
        let actions = self.policy.tick(now_s, visible);
        for act in actions {
            let cmd = match act {
                Action::CapLp { mhz } => OobCommand::FreqCap { target: Priority::Low, mhz },
                Action::CapHp { mhz } => OobCommand::FreqCap { target: Priority::High, mhz },
                Action::UncapLp => OobCommand::Uncap { target: Priority::Low },
                Action::UncapHp => OobCommand::Uncap { target: Priority::High },
                Action::Brake => OobCommand::PowerBrake,
                Action::ReleaseBrake => OobCommand::ReleaseBrake,
            };
            self.issue_cmd(now_s, cmd);
        }
        self.reconcile_oob(now_s);
    }

    /// Issue one command through the OOB channel, recording the attempt
    /// time per class (the re-issue timeout clock).
    fn issue_cmd(&mut self, now_s: f64, cmd: OobCommand) {
        match cmd {
            OobCommand::FreqCap { target: Priority::Low, .. }
            | OobCommand::Uncap { target: Priority::Low } => self.lp_last_issue_s = now_s,
            OobCommand::FreqCap { target: Priority::High, .. }
            | OobCommand::Uncap { target: Priority::High } => self.hp_last_issue_s = now_s,
            OobCommand::PowerBrake | OobCommand::ReleaseBrake => {}
        }
        if let Some(apply_at) = self.oob.issue(now_s, cmd) {
            self.queue.schedule_at(secs(apply_at), Ev::OobApply);
        }
    }

    /// Re-issue slow-path commands that were *lost* (never acknowledged)
    /// once the apply timeout has elapsed — the idempotent-retry loop a
    /// real rack manager runs over SMBPBI. Commands that were
    /// acknowledged are never re-issued, so a cap-ignoring server (acks,
    /// does not apply) is invisible here; containing it is the policy
    /// engine's escalation job, not the transport's.
    fn reconcile_oob(&mut self, now_s: f64) {
        let timeout = self.cfg.exp.row.oob_latency_s * 1.5 + self.cfg.exp.row.telemetry_period_s;
        let intent = self.policy.intent();
        if intent.lp_cap_mhz != self.acked_lp
            && now_s - self.lp_last_issue_s > timeout
            && !self.oob.has_pending(|c| targets(c, Priority::Low))
        {
            self.report.resilience.reissued_commands += 1;
            let cmd = match intent.lp_cap_mhz {
                Some(mhz) => OobCommand::FreqCap { target: Priority::Low, mhz },
                None => OobCommand::Uncap { target: Priority::Low },
            };
            self.issue_cmd(now_s, cmd);
        }
        if intent.hp_cap_mhz != self.acked_hp
            && now_s - self.hp_last_issue_s > timeout
            && !self.oob.has_pending(|c| targets(c, Priority::High))
        {
            self.report.resilience.reissued_commands += 1;
            let cmd = match intent.hp_cap_mhz {
                Some(mhz) => OobCommand::FreqCap { target: Priority::High, mhz },
                None => OobCommand::Uncap { target: Priority::High },
            };
            self.issue_cmd(now_s, cmd);
        }
    }

    fn on_oob_apply(&mut self, now_s: f64) {
        for pending in self.oob.due(now_s) {
            match pending.cmd {
                OobCommand::FreqCap { target, mhz } => {
                    self.report.cap_commands += 1;
                    self.ack(target, Some(mhz));
                    for idx in 0..self.servers.len() {
                        // Cap-ignoring servers acknowledge (the ack is
                        // recorded above) but do not change frequency.
                        if self.servers[idx].priority == target && !self.cap_ignore[idx] {
                            self.set_server_cap(idx, Some(mhz), now_s);
                        }
                    }
                }
                OobCommand::Uncap { target } => {
                    self.report.uncap_commands += 1;
                    self.ack(target, None);
                    for idx in 0..self.servers.len() {
                        if self.servers[idx].priority == target && !self.cap_ignore[idx] {
                            self.set_server_cap(idx, None, now_s);
                        }
                    }
                }
                // The brake is a hardware signal below the wedged
                // firmware: cap-ignoring servers obey it too.
                OobCommand::PowerBrake => {
                    self.report.brake_commands += 1;
                    self.set_brake(true, now_s);
                }
                OobCommand::ReleaseBrake => self.set_brake(false, now_s),
            }
        }
    }

    /// Record a delivered (acknowledged) slow-path cap state per class.
    fn ack(&mut self, target: Priority, cap: Option<f64>) {
        match target {
            Priority::Low => self.acked_lp = cap,
            Priority::High => self.acked_hp = cap,
        }
    }

    // ---- training-job driver (§2.4 / §7) ---------------------------------

    /// Cap governing a job right now. Every member shares the LP class
    /// (training is priority-pinned) and the brake is row-wide, so one
    /// member is representative.
    fn train_cap(&self, j: usize) -> CapMode {
        self.cap_mode(self.train_jobs[j].servers[0])
    }

    /// Push the job's current waveform level to every member server —
    /// one event, all members: this is the cross-server iteration
    /// synchronization that makes row-level swings coordinate.
    fn apply_train_level(&mut self, j: usize) {
        let level = self.train_jobs[j].model.profile.phase_levels()[self.train_jobs[j].phase_idx];
        let members = std::mem::take(&mut self.train_jobs[j].servers);
        for &idx in &members {
            self.servers[idx].train_level = level;
            self.refresh_power(idx);
        }
        self.train_jobs[j].servers = members;
    }

    fn schedule_train_phase(&mut self, j: usize) {
        let job = &self.train_jobs[j];
        let b = job.model.profile.phase_bounds();
        let end_s = job.iter_started_s + job.iter_wall_s * b[job.phase_idx + 1];
        let gen = job.gen;
        // Same +1 µs guard as request phases: integer-microsecond
        // rounding must never land before the true boundary.
        self.queue.schedule_at(secs(end_s) + 1, Ev::TrainPhase { job: j as u32, gen });
    }

    /// Begin an iteration. Timing is fixed by the cap active *now*:
    /// caps arriving mid-iteration change power immediately (via
    /// [`Self::refresh_power`]) but stretch timing only from the next
    /// gradient-sync barrier on — barriers quantize the performance
    /// effect at iteration granularity.
    fn start_train_iteration(&mut self, j: usize, now_s: f64) {
        let cap = self.train_cap(j);
        let job = &mut self.train_jobs[j];
        job.gen = job.gen.wrapping_add(1);
        job.phase_idx = 0;
        job.iter_started_s = now_s;
        job.iter_wall_s = job.model.iter_time_s(cap);
        self.apply_train_level(j);
        self.schedule_train_phase(j);
    }

    fn on_train_phase(&mut self, j: usize, gen: u32, now_s: f64) {
        if self.train_jobs[j].gen != gen {
            return; // stale (the job has since restarted an iteration)
        }
        if self.train_jobs[j].phase_idx + 1 >= 4 {
            // Sync barrier reached: the iteration is complete.
            let wall = now_s - self.train_jobs[j].iter_started_s;
            self.report.train.record(wall);
            self.start_train_iteration(j, now_s);
        } else {
            self.train_jobs[j].phase_idx += 1;
            self.apply_train_level(j);
            self.schedule_train_phase(j);
        }
    }

    // ---- fault injection (see crate::faults) -----------------------------

    /// A fault episode begins: degrade the corresponding control-plane
    /// link. Violations from here on attribute to this incident.
    fn on_fault_start(&mut self, i: usize, now_s: f64) {
        self.cur_incident = Some(i);
        let ev = self.fault_events[i];
        match ev.kind {
            FaultKind::TelemetryFreeze => self.telemetry.freeze(now_s, ev.end_s()),
            FaultKind::OobStorm { loss_prob, latency_mult, jitter_frac } => {
                self.oob.set_unreliability(loss_prob, jitter_frac);
                self.oob.set_latency_mult(latency_mult);
            }
            FaultKind::CapIgnore { server_frac } => {
                let n = ((server_frac * self.servers.len() as f64).ceil() as usize)
                    .min(self.servers.len());
                for idx in 0..n {
                    self.cap_ignore[idx] = true;
                }
            }
            FaultKind::MeterBias { mult } => self.meter_bias = mult,
            FaultKind::FeedLoss { budget_frac } => {
                // Close the accounting segment under the old budget
                // before the effective budget changes.
                self.settle_energy();
                self.budget_mult = budget_frac.max(1e-6);
            }
        }
    }

    /// A fault episode ends: restore the baseline control plane.
    fn on_fault_end(&mut self, i: usize, now_s: f64) {
        let ev = self.fault_events[i];
        match ev.kind {
            // The freeze window expires by itself inside the buffer.
            FaultKind::TelemetryFreeze => {}
            FaultKind::OobStorm { .. } => {
                self.oob.set_unreliability(self.cfg.oob_loss_prob, self.cfg.oob_jitter_frac);
                self.oob.set_latency_mult(1.0);
            }
            FaultKind::CapIgnore { .. } => {
                // The wedged firmware recovers and drains its queue:
                // converge every affected server to the last
                // acknowledged cap state of its class.
                for idx in 0..self.servers.len() {
                    if !self.cap_ignore[idx] {
                        continue;
                    }
                    self.cap_ignore[idx] = false;
                    let cap = match self.servers[idx].priority {
                        Priority::Low => self.acked_lp,
                        Priority::High => self.acked_hp,
                    };
                    self.set_server_cap(idx, cap, now_s);
                }
            }
            FaultKind::MeterBias { .. } => self.meter_bias = 1.0,
            FaultKind::FeedLoss { .. } => {
                self.settle_energy();
                self.budget_mult = 1.0;
            }
        }
    }

    /// Per-incident containment outcomes, written at finalize.
    fn finalize_incidents(&mut self) {
        let scaled_w = self.cfg.power_scale * self.row_power_w;
        let still_violating = scaled_w > self.row.budget_w * self.budget_mult;
        for (i, f) in self.fault_events.iter().enumerate() {
            let time_to_contain_s = match self.incident_last_violation[i] {
                None => 0.0,
                Some(_) if still_violating && self.cur_incident == Some(i) => f64::INFINITY,
                Some(last) => (last - f.start_s).max(0.0),
            };
            self.report.resilience.incidents.push(IncidentOutcome {
                label: f.kind.label().to_string(),
                start_s: f.start_s,
                end_s: f.end_s(),
                time_to_contain_s,
            });
        }
    }

    // ---- main loop -------------------------------------------------------

    fn run(mut self) -> RunReport {
        // Initial power state.
        for idx in 0..self.servers.len() {
            self.refresh_power(idx);
        }
        // Seed events. Training servers take no request arrivals: their
        // load is the iteration waveform, driven by TrainStart below.
        for idx in 0..self.servers.len() {
            if self.servers[idx].kind == JobKind::Training {
                continue;
            }
            let t = self.servers[idx].arrivals.next_after(0.0);
            self.queue.schedule_at(secs(t), Ev::Arrival { server: idx as u32 });
        }
        for j in 0..self.train_jobs.len() {
            let start = self.train_jobs[j].start_s;
            self.queue.schedule_at(secs(start), Ev::TrainStart { job: j as u32 });
        }
        self.queue.schedule_at(0, Ev::Telemetry);
        if self.cfg.series_sample_s > 0.0 {
            self.queue.schedule_at(0, Ev::SampleSeries);
        }
        // Fault timeline: an empty plan schedules nothing, keeping the
        // run bit-identical to one with no plan at all.
        for i in 0..self.fault_events.len() {
            let f = self.fault_events[i];
            self.queue.schedule_at(secs(f.start_s), Ev::FaultStart { fault: i as u32 });
            self.queue.schedule_at(secs(f.end_s()), Ev::FaultEnd { fault: i as u32 });
        }
        self.queue.schedule_at(self.horizon, Ev::End);

        while let Some((t, ev)) = self.queue.pop() {
            let now_s = to_secs(t);
            self.now_s = now_s;
            match ev {
                Ev::Arrival { server } => self.on_arrival(server as usize, now_s),
                Ev::PhaseEnd { server, gen } => self.on_phase_end(server as usize, gen, now_s),
                Ev::Telemetry => self.on_telemetry(now_s),
                Ev::OobApply => self.on_oob_apply(now_s),
                Ev::TrainStart { job } => self.start_train_iteration(job as usize, now_s),
                Ev::TrainPhase { job, gen } => self.on_train_phase(job as usize, gen, now_s),
                Ev::SampleSeries => {
                    self.report.power_series.push((now_s, self.normalized_row_power()));
                    self.queue.schedule_in(secs(self.cfg.series_sample_s), Ev::SampleSeries);
                }
                Ev::FaultStart { fault } => self.on_fault_start(fault as usize, now_s),
                Ev::FaultEnd { fault } => self.on_fault_end(fault as usize, now_s),
                Ev::End => break,
            }
            if t >= self.horizon {
                break;
            }
        }

        // Finalize. Close the last ground-truth accounting segment at
        // the horizon, then score the injected incidents.
        self.now_s = to_secs(self.horizon);
        self.settle_energy();
        self.finalize_incidents();
        if self.braked {
            self.report.brake_time_s += to_secs(self.horizon) - self.brake_engaged_at;
        }
        self.report.brake_events = self.policy.brake_events;
        self.report.duration_s = to_secs(self.horizon);
        self.report.events = self.queue.popped();
        let (peak, p99, mean) = self.telemetry.utilization();
        self.report.power_peak = peak;
        self.report.power_p99 = p99;
        self.report.power_mean = mean;
        let spikes = self.telemetry.spike_stats(&[2.0, 5.0, 40.0]);
        self.report.spike_2s = spikes[0].max_rise;
        self.report.spike_5s = spikes[1].max_rise;
        self.report.spike_40s = spikes[2].max_rise;
        self.report
    }
}

/// Fit `power_scale` so the base row (baseline servers, no capping)
/// peaks at `target_peak` (Table 2 inference: 0.79). Returns the scale.
pub fn calibrate(target_peak: f64, weeks: f64, seed: u64) -> f64 {
    let mut cfg = SimConfig {
        policy_kind: PolicyKind::NoCap,
        weeks,
        power_scale: 1.0,
        ..Default::default()
    };
    cfg.exp.seed = seed;
    let report = run(&cfg);
    target_peak / report.power_peak
}

/// The telemetry-visible power series of a run (for trace MAPE checks).
pub fn power_series_of(cfg: &SimConfig) -> Vec<(f64, f64)> {
    let mut c = cfg.clone();
    c.series_sample_s = if c.series_sample_s > 0.0 { c.series_sample_s } else { 60.0 };
    run(&c).power_series
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> SimConfig {
        let mut cfg = SimConfig::default();
        cfg.weeks = 0.05; // ~8.4 hours
        cfg.deployed_servers = 12;
        cfg.exp.row.num_servers = 12;
        cfg.exp.seed = 42;
        // Small rows multiplex fewer prompt spikes, so their relative
        // variance is higher; calibrate the 12-server test row separately
        // (production rows are 40+, using DEFAULT_POWER_SCALE).
        cfg.power_scale = 1.35;
        cfg
    }

    #[test]
    fn base_run_completes_requests_without_brakes() {
        let mut cfg = quick_cfg();
        cfg.weeks = 0.1;
        let report = run(&cfg);
        assert!(report.hp.completed > 50, "hp completed = {}", report.hp.completed);
        assert!(report.lp.completed > 50);
        assert_eq!(report.brake_events, 0);
        assert!(report.power_peak > 0.3 && report.power_peak < 1.0, "peak={}", report.power_peak);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = quick_cfg();
        let mut a = run(&cfg);
        let mut b = run(&cfg);
        assert_eq!(a.hp.completed, b.hp.completed);
        assert_eq!(a.lp.completed, b.lp.completed);
        assert_eq!(a.brake_events, b.brake_events);
        assert!((a.power_peak - b.power_peak).abs() < 1e-12);
        assert!((a.hp.latency.p99() - b.hp.latency.p99()).abs() < 1e-12);
    }

    #[test]
    fn oversubscription_raises_power() {
        let base = run(&quick_cfg());
        let mut over_cfg = quick_cfg();
        over_cfg.deployed_servers = 16; // +33%
        let over = run(&over_cfg);
        assert!(over.power_mean > base.power_mean * 1.15,
            "base={} over={}", base.power_mean, over.power_mean);
    }

    #[test]
    fn heavy_oversubscription_nocap_brakes_polca_does_not() {
        let mut nocap = quick_cfg();
        nocap.policy_kind = PolicyKind::NoCap;
        nocap.deployed_servers = 22; // +83%: pushes past the breaker
        nocap.weeks = 0.08;
        let r_nocap = run(&nocap);
        assert!(r_nocap.brake_events > 0, "no-cap at +83% must brake");

        let mut polca = nocap.clone();
        polca.policy_kind = PolicyKind::Polca;
        let r_polca = run(&polca);
        assert!(
            r_polca.brake_events <= r_nocap.brake_events,
            "POLCA ({}) must brake no more than No-cap ({})",
            r_polca.brake_events,
            r_nocap.brake_events
        );
        // POLCA's caps must push P99 power below No-cap's.
        assert!(r_polca.power_p99 <= r_nocap.power_p99 + 0.02);
    }

    #[test]
    fn polca_caps_impact_lp_more_than_hp() {
        let mut cfg = quick_cfg();
        cfg.deployed_servers = 18; // +50%: capping definitely active
        cfg.weeks = 0.08;
        let (_, impact) = run_with_impact(&cfg);
        assert!(
            impact.lp_p99 >= impact.hp_p99 - 0.02,
            "LP p99 {} should be >= HP p99 {}",
            impact.lp_p99,
            impact.hp_p99
        );
    }

    #[test]
    fn baseline_has_zero_impact_on_itself() {
        let cfg = quick_cfg().baseline();
        let (_, impact) = run_with_impact(&cfg);
        assert!(impact.hp_p50 < 1e-9 && impact.lp_p99 < 1e-9);
        assert_eq!(impact.brake_events, 0);
    }

    #[test]
    fn no_oversubscription_meets_slo() {
        let mut cfg = quick_cfg();
        cfg.weeks = 0.08;
        let (_, impact) = run_with_impact(&cfg);
        assert!(
            impact.meets_slo(&cfg.exp.slo),
            "{:?}",
            impact.slo_violations(&cfg.exp.slo)
        );
    }

    #[test]
    fn work_conservation_under_caps() {
        // Every arrival is eventually completed or dropped or in flight:
        // completed + dropped <= arrivals, and nothing is double counted.
        let mut cfg = quick_cfg();
        cfg.deployed_servers = 16;
        let report = run(&cfg);
        let total = report.hp.completed + report.lp.completed
            + report.hp.dropped + report.lp.dropped;
        assert!(total > 100);
        // All recorded latencies are >= nominal (impact >= 0) by metric
        // construction; peak power must never be absurd.
        assert!(report.power_peak < 2.0);
    }

    #[test]
    fn mixed_zero_fraction_is_bit_identical_to_none() {
        let mut a_cfg = quick_cfg();
        a_cfg.weeks = 0.03;
        let mut b_cfg = a_cfg.clone();
        b_cfg.mixed = Some(MixedRowConfig::default()); // training_fraction 0.0
        let mut a = run(&a_cfg);
        let mut b = run(&b_cfg);
        assert_eq!(a.hp.completed, b.hp.completed);
        assert_eq!(a.lp.completed, b.lp.completed);
        assert_eq!(a.events, b.events);
        assert!((a.power_peak - b.power_peak).abs() == 0.0);
        assert!((a.hp.latency.p99() - b.hp.latency.p99()).abs() == 0.0);
        assert_eq!(b.train.iters, 0);
    }

    #[test]
    fn pure_training_row_runs_iterations_at_tdp_class_power() {
        let mut cfg = quick_cfg();
        cfg.weeks = 0.01; // ~1.7 h
        cfg.policy_kind = PolicyKind::NoCap;
        cfg.mixed = Some(MixedRowConfig { training_fraction: 1.0, ..Default::default() });
        let report = run(&cfg);
        // No inference traffic at all on a pure-training row.
        assert_eq!(report.hp.completed + report.lp.completed, 0);
        assert!(report.train.iters > 500, "iters={}", report.train.iters);
        // §2.4: training sits just under provisioned power — far above
        // the inference mean — independent of the inference power_scale.
        assert!(
            report.power_peak > 0.85 && report.power_peak < 1.0,
            "peak={}",
            report.power_peak
        );
        // Uncapped iterations run at nominal speed (µs event rounding only).
        assert!(report.train.inflation() < 1e-4, "inflation={}", report.train.inflation());
        assert_eq!(report.brake_events, 0);
    }

    #[test]
    fn polca_caps_training_and_inflates_iteration_time() {
        // A pure-training row idles above T2 (0.89), so POLCA must cap
        // it — and the cost shows up as iteration-time inflation, never
        // as request latency (§7: training is always cappable).
        let mut cfg = quick_cfg();
        cfg.weeks = 0.02;
        cfg.policy_kind = PolicyKind::Polca;
        cfg.mixed = Some(MixedRowConfig { training_fraction: 1.0, ..Default::default() });
        let report = run(&cfg);
        assert!(report.cap_commands > 0, "row above T2 must engage LP caps");
        assert!(
            report.train.inflation() > 0.005,
            "capped training must slow down: inflation={}",
            report.train.inflation()
        );
        assert_eq!(report.hp.completed, 0);
    }

    #[test]
    fn training_fraction_interpolates_power_monotonically() {
        let mut peaks = Vec::new();
        for frac in [0.0, 0.5, 1.0] {
            let mut cfg = quick_cfg();
            cfg.weeks = 0.05;
            cfg.policy_kind = PolicyKind::NoCap;
            cfg.mixed = Some(MixedRowConfig { training_fraction: frac, ..Default::default() });
            peaks.push(run(&cfg).power_peak);
        }
        assert!(peaks[0] < peaks[1] && peaks[1] < peaks[2], "{peaks:?}");
    }

    #[test]
    fn mixed_run_is_deterministic() {
        let mut cfg = quick_cfg();
        cfg.weeks = 0.02;
        cfg.mixed = Some(MixedRowConfig {
            training_fraction: 0.5,
            servers_per_job: 3,
            job_stagger_s: 2.0,
            ..Default::default()
        });
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.train.iters, b.train.iters);
        assert_eq!(a.hp.completed, b.hp.completed);
        assert!((a.power_peak - b.power_peak).abs() == 0.0);
        assert!((a.train.iter_time_sum_s - b.train.iter_time_sum_s).abs() == 0.0);
    }

    #[test]
    fn empty_fault_plan_is_inert() {
        let mut a_cfg = quick_cfg();
        a_cfg.weeks = 0.03;
        let mut b_cfg = a_cfg.clone();
        b_cfg.faults = Some(FaultPlan::new());
        let a = run(&a_cfg);
        let b = run(&b_cfg);
        // Bit-identical, including the (empty) resilience accounting.
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert!(a.resilience.incidents.is_empty());
    }

    #[test]
    fn feed_loss_is_contained_by_the_brake_path() {
        // Probe the clean run for its diurnal peak so the feed loss is
        // injected when it actually bites.
        let mut probe = quick_cfg();
        probe.weeks = 0.1;
        probe.policy_kind = PolicyKind::NoCap;
        probe.series_sample_s = 120.0;
        let horizon = probe.weeks * 7.0 * 86_400.0;
        let series = run(&probe).power_series;
        let &(t_peak, p_peak) = series
            .iter()
            .filter(|&&(t, _)| t < horizon - 7200.0)
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        // Cut the budget to well under the peak draw: the effective
        // reading crosses 1.0, and only the brake path can answer.
        let mut cfg = probe.clone();
        cfg.series_sample_s = 0.0;
        let window_s = 1800.0;
        let budget_frac = p_peak / 1.3;
        cfg.faults = Some(FaultPlan::new().with(
            FaultKind::FeedLoss { budget_frac },
            (t_peak - window_s / 2.0).max(0.0),
            window_s,
        ));
        let report = run(&cfg);
        assert_eq!(report.resilience.incidents.len(), 1);
        let inc = report.resilience.incidents[0].clone();
        assert!(report.resilience.violation_s > 0.0, "the cut must bite");
        assert!(inc.contained(), "{inc:?}");
        assert!(report.brake_commands > 0, "containment must have used the brake");
        // The brake (reported reading > 1.0 exactly when the effective
        // budget is violated) keeps the violation to a fraction of the
        // episode — the row is never left over budget for long.
        assert!(
            report.resilience.violation_s < 0.8 * window_s,
            "violation {}s over a {}s episode",
            report.resilience.violation_s,
            window_s
        );
        assert!(report.resilience.peak_overshoot_w > 0.0);
    }

    #[test]
    fn full_telemetry_dropout_disables_the_control_loop() {
        let mut cfg = quick_cfg();
        cfg.weeks = 0.08;
        cfg.deployed_servers = 22; // heavy: the clean run would cap/brake
        let horizon = cfg.weeks * 7.0 * 86_400.0;
        cfg.faults = Some(FaultPlan::new().with(
            FaultKind::TelemetryFreeze,
            0.0,
            horizon + 1.0,
        ));
        let report = run(&cfg);
        // The policy never saw a reading: no caps, no brakes — and the
        // ground-truth accounting shows the row went over budget.
        assert_eq!(report.cap_commands, 0);
        assert_eq!(report.brake_commands, 0);
        assert!(report.resilience.violation_s > 0.0);
        assert!(report.resilience.true_peak_norm > 1.0);
    }

    #[test]
    fn meter_bias_under_reports_the_peak() {
        let mut clean_cfg = quick_cfg();
        clean_cfg.weeks = 0.04;
        clean_cfg.policy_kind = PolicyKind::NoCap;
        let mut biased_cfg = clean_cfg.clone();
        let horizon = biased_cfg.weeks * 7.0 * 86_400.0;
        biased_cfg.faults = Some(FaultPlan::new().with(
            FaultKind::MeterBias { mult: 0.5 },
            0.0,
            horizon + 1.0,
        ));
        let clean = run(&clean_cfg);
        let biased = run(&biased_cfg);
        // Reported statistics shrink with the bias; the ground truth
        // does not move (same workload, same NoCap policy).
        assert!((biased.power_peak - 0.5 * clean.power_peak).abs() < 1e-9);
        assert!(
            (biased.resilience.true_peak_norm - clean.resilience.true_peak_norm).abs() < 1e-12
        );
    }

    #[test]
    fn oob_loss_storm_triggers_reissue_not_silence() {
        let mut cfg = quick_cfg();
        cfg.weeks = 0.08;
        cfg.deployed_servers = 18; // capping definitely intended
        let horizon = cfg.weeks * 7.0 * 86_400.0;
        cfg.faults = Some(FaultPlan::new().with(
            FaultKind::OobStorm { loss_prob: 1.0, latency_mult: 1.0, jitter_frac: 0.0 },
            0.0,
            horizon + 1.0,
        ));
        let report = run(&cfg);
        // Every slow-path command is lost, so none applies — but the
        // rack manager keeps retrying after the apply timeout.
        assert_eq!(report.cap_commands, 0);
        assert!(report.resilience.reissued_commands > 0);
    }

    #[test]
    fn calibration_hits_target_peak() {
        let mut cfg = SimConfig::default();
        cfg.weeks = 0.15;
        cfg.deployed_servers = 40;
        cfg.policy_kind = PolicyKind::NoCap;
        cfg.exp.seed = 7;
        let report = run(&cfg);
        // With the shipped DEFAULT_POWER_SCALE the base row should peak
        // near the Table-2 inference utilization.
        assert!(
            (0.70..=0.88).contains(&report.power_peak),
            "peak={} (rescale DEFAULT_POWER_SCALE?)",
            report.power_peak
        );
    }
}
