//! The row-level cluster simulator — the paper's §6 evaluation vehicle.
//!
//! A discrete-event simulation of one datacenter row: `deployed` DGX
//! servers behind a PDU breaker provisioned for `baseline` servers,
//! each dedicated to a Table-4 service on BLOOM-176B (§6.1), with:
//!
//!   * non-homogeneous Poisson arrivals (diurnal, §3.2),
//!   * a one-request buffer per server (§6.3 queueing model),
//!   * per-request two-phase execution (prompt/token) whose speed follows
//!     the current frequency cap ([`crate::perfmodel::RequestExec`]),
//!   * instantaneous row power aggregated from per-server phase power,
//!   * PDU telemetry with 2 s delay driving the policy engine,
//!   * OOB cap commands with 40 s latency, powerbrake with 5 s (Table 1),
//!   * the powerbrake backstop when real power exceeds the breaker.
//!
//! # Layers
//!
//! The simulator is a composition of six layers, each in its own
//! module with an explicit boundary (state it owns, `Sim` methods that
//! mutate it):
//!
//! | layer          | owns                                                        |
//! |----------------|-------------------------------------------------------------|
//! | [`core`](self::core) | event vocabulary, queue, horizon, the dispatch loop   |
//! | [`servers`]    | row provisioning, per-server state, request lifecycle       |
//! | [`control`]    | telemetry → policy → OOB issue/ack/reconcile, the brake     |
//! | [`training`]   | the mixed-row phase driver ([`MixedRowConfig`], §2.4/§7)    |
//! | [`faults`]     | episode overlay: meter bias, budget cuts, cap-ignore        |
//! | [`accounting`] | energy accumulator, [`crate::metrics::RunReport`] bookkeeping |
//!
//! [`calib`] carries the row-power calibration (`power_scale`) with its
//! memoized per-row-size cache, plus the memoized per-workload
//! mean-service estimation behind `ServerLayer::new`; the private
//! `powermemo` module is the exact-input power-evaluation memo on the
//! `refresh_power` hot path (see `docs/PERFORMANCE.md` for the whole
//! hot-path anatomy). This module re-exports the public API;
//! golden tests (`tests/golden_simulation.rs`) pin the layered
//! composition bit-identical to the pre-split monolith at the same
//! seed, and batch surfaces fan runs out through [`crate::exec`].
//!
//! # Power calibration
//!
//! The analytic single-request server model understates the sustained
//! draw of production serving, so a scalar `power_scale` is fitted once
//! so the *base* row peaks at the published Table-2 inference
//! utilization (79%) — see [`calib`] for the fit and the cache.
//!
//! # Mixed-workload rows (§2.4 / §7)
//!
//! A [`MixedRowConfig`] colocates synchronized training jobs with the
//! inference services — see [`training`] for the phase-driver contract
//! (caps change power immediately, stretch the *next* iteration).
//!
//! # Fault injection (§6/§7 robustness)
//!
//! A [`crate::faults::FaultPlan`] on [`SimConfig::faults`] interleaves
//! control-plane fault episodes with the workload — see [`faults`];
//! ground-truth violation accounting is settled exactly on every power
//! change in [`accounting`], independent of what the possibly-lying
//! meter reports. docs/RELIABILITY.md is the runbook.

pub mod accounting;
pub mod adapt;
pub mod calib;
pub mod control;
pub mod core;
pub mod faults;
mod powermemo;
pub mod servers;
pub mod training;

#[cfg(test)]
mod tests;

pub use calib::{
    calibrate, calibration_runs, mean_service_estimations, power_scale_for_row, power_series_of,
    DEFAULT_POWER_SCALE,
};
pub use training::MixedRowConfig;

use crate::config::ExperimentConfig;
use crate::faults::FaultPlan;
use crate::metrics::RunReport;
use crate::policy::adapt::AdaptConfig;
use crate::policy::engine::PolicyKind;
use crate::workload::arrivals::DriftConfig;

/// Simulation parameters for one run.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Row/policy/SLO parameters (paper Tables 1/3/5) and the seed.
    pub exp: ExperimentConfig,
    /// Which power-management policy drives the row.
    pub policy_kind: PolicyKind,
    /// Servers actually deployed (baseline = exp.row.num_servers;
    /// more = oversubscribed).
    pub deployed_servers: usize,
    /// Simulated horizon in weeks (fractions allowed for quick runs).
    pub weeks: f64,
    /// Catalog model every server is dedicated to (§6.1: BLOOM-176B).
    pub model_name: String,
    /// Override the global LP share (Fig 15b sweep).
    pub lp_fraction_override: Option<f64>,
    /// Row-power calibration factor (see [`calib`]).
    pub power_scale: f64,
    /// Multiplier on per-workload power (Fig 17 "+5%" robustness study).
    pub workload_power_mult: f64,
    /// Target server busy fraction at the diurnal peak (drives arrivals).
    pub peak_utilization: f64,
    /// Sample the power series every this many seconds (0 = off).
    pub series_sample_s: f64,
    /// OOB command-loss probability (0.0 = the paper's reliable channel).
    pub oob_loss_prob: f64,
    /// OOB apply-latency jitter fraction (uniform ±).
    pub oob_jitter_frac: f64,
    /// When false, the power manager is disconnected entirely (no caps,
    /// no brake): the unthrottled counterfactual used as the latency
    /// baseline for impact measurement (see [`crate::metrics`]).
    pub protection: bool,
    /// Override the server power model (heterogeneous SKUs — see
    /// [`crate::fleet::sku`]). `None` derives the DGX-A100 default from
    /// the catalog calibration, as the paper does.
    pub server_model: Option<crate::power::server::ServerPowerModel>,
    /// Throughput multiplier applied to the model's latency anchors
    /// (prompt/decode tokens-per-second). Faster SKUs (H100-class) serve
    /// the same model at a multiple of the A100 anchors.
    pub perf_mult: f64,
    /// Diurnal phase offset (s) applied to every arrival stream: this
    /// row serves a region whose traffic peaks earlier/later than site
    /// time (fleet layer staggers cluster peaks with this).
    pub diurnal_phase_s: f64,
    /// Mixed-row configuration (`None` = the paper's inference-only
    /// row; `Some` with `training_fraction: 0.0` is bit-identical to
    /// `None` — a tested invariant).
    pub mixed: Option<MixedRowConfig>,
    /// Fault-injection timeline (`None` = the paper's well-behaved
    /// control plane; `Some` with an empty plan is bit-identical to
    /// `None` — a tested invariant, see [`crate::faults`]).
    pub faults: Option<FaultPlan>,
    /// Enable the policy engine's containment escalation: brake when the
    /// full cap set has visibly failed to pull the reading under T2 for
    /// this many seconds (`None` = paper behavior; see
    /// [`crate::policy::engine::PolicyEngine::escalate_to_brake_after_s`]).
    pub brake_escalation_s: Option<f64>,
    /// Adaptive outer-loop controller ([`crate::policy::adapt`]):
    /// `None` (the default) schedules no `RetuneCheck` events and is
    /// bit-identical to a pre-adapt build — the same contract as
    /// `mixed`/`faults` above.
    pub adapt: Option<AdaptConfig>,
    /// Long-horizon demand drift on every arrival stream
    /// ([`crate::workload::arrivals::DriftConfig`]); `None` keeps the
    /// samplers on the pre-drift code path, bit-identically.
    pub drift: Option<DriftConfig>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            exp: ExperimentConfig::default(),
            policy_kind: PolicyKind::Polca,
            deployed_servers: 40,
            weeks: 1.0,
            model_name: "BLOOM-176B".to_string(),
            lp_fraction_override: None,
            power_scale: DEFAULT_POWER_SCALE,
            workload_power_mult: 1.0,
            peak_utilization: 0.85,
            series_sample_s: 0.0,
            oob_loss_prob: 0.0,
            oob_jitter_frac: 0.0,
            protection: true,
            server_model: None,
            perf_mult: 1.0,
            diurnal_phase_s: 0.0,
            mixed: None,
            faults: None,
            brake_escalation_s: None,
            adapt: None,
            drift: None,
        }
    }
}

impl SimConfig {
    /// The unthrottled counterfactual of this configuration: identical
    /// workload realization (same seed), power manager disconnected.
    /// The adaptive controller is disconnected too (it is part of the
    /// power manager), but demand drift stays — the baseline must see
    /// the same arrival realization.
    pub fn baseline(&self) -> SimConfig {
        let mut b = self.clone();
        b.protection = false;
        b.policy_kind = PolicyKind::NoCap;
        b.series_sample_s = 0.0;
        b.adapt = None;
        b
    }
}

/// Run one simulation; returns the report.
pub fn run(cfg: &SimConfig) -> RunReport {
    self::core::run_sim(cfg)
}

/// Run one simulation with an [`Observer`](crate::obs::Observer)
/// attached. Observation is passive: the report is bit-identical to
/// [`run`] on the same config (the passivity property in
/// `tests/integration_obs.rs`); the observer additionally receives the
/// event stream, series samples, and hot-path counters.
pub fn run_observed<O: crate::obs::Observer>(cfg: &SimConfig, obs: &mut O) -> RunReport {
    self::core::run_sim_observed(cfg, obs)
}

/// Run a policy config and its paired baseline; return (report, impact).
pub fn run_with_impact(cfg: &SimConfig) -> (RunReport, crate::metrics::ImpactSummary) {
    let mut report = run(cfg);
    let mut base = run(&cfg.baseline());
    let impact = report.impact_vs(&mut base);
    (report, impact)
}

/// [`run_with_impact`] with an observer on the policy run (the paired
/// baseline is a counterfactual and stays unobserved).
pub fn run_with_impact_observed<O: crate::obs::Observer>(
    cfg: &SimConfig,
    obs: &mut O,
) -> (RunReport, crate::metrics::ImpactSummary) {
    let mut report = run_observed(cfg, obs);
    let mut base = run(&cfg.baseline());
    let impact = report.impact_vs(&mut base);
    (report, impact)
}
