//! Adaptive-control layer: the outer loop that retunes the row online.
//!
//! Owns the [`AdaptController`] state machine plus the per-window
//! feedback accumulators it consumes — the peak normalized meter
//! reading (fed from [`super::control`]'s telemetry hook), the HP
//! latency slowdown (fed from the request-completion path in
//! [`super::servers`]), and deltas of the ground-truth violation
//! integral and brake count snapshotted at each window boundary.
//!
//! The layer is RNG-free and entirely event-driven: a single
//! `Ev::RetuneCheck` rescheduled every `window_s` closes the window,
//! asks the controller for a decision, and actuates it by writing the
//! (T1, T2) rung into the live policy engine and resizing the *active*
//! prefix of the deployed row. Inactive servers stay racked (arrivals
//! are still scheduled and sampled, preserving every random stream
//! bit-for-bit) but their requests are shed to the rest of the fleet.
//!
//! With [`SimConfig::adapt`](super::SimConfig) unset, no `RetuneCheck`
//! is ever scheduled and none of the hooks fire — the run is
//! bit-identical to a pre-adapt build (the same contract as
//! `mixed`/`faults`, pinned by `tests/integration_adapt.rs`).

use crate::obs::{emit_diag, DiagEvent, EventKind, Observer};
use crate::policy::adapt::{AdaptConfig, AdaptController, AdaptReport, Verdict, WindowObs};
use crate::sim::{secs, to_secs};

use super::core::{Ev, Sim};
use super::SimConfig;

/// Controller state, window accumulators, and actuation bookkeeping.
#[derive(Debug, Clone)]
pub(crate) struct AdaptLayer {
    pub(crate) ctl: AdaptController,
    /// Provisioned baseline (what the breaker was sized for).
    pub(crate) num_servers: usize,
    /// Servers physically racked; the hard ceiling on actuation.
    pub(crate) deployed: usize,
    /// Servers currently taking traffic (the actuated level).
    pub(crate) active_servers: usize,
    /// Peak normalized meter reading seen this window.
    pub(crate) win_peak_norm: f64,
    /// HP latency sums this window (actual vs nominal), for slowdown.
    pub(crate) win_hp_actual: f64,
    pub(crate) win_hp_nominal: f64,
    /// Run-total snapshots taken at the last window boundary, so each
    /// window sees only its own delta.
    pub(crate) last_violation_s: f64,
    pub(crate) last_brakes: u64,
    /// Time-weighted level integral (for the mean-added-level metric).
    pub(crate) level_time_acc: f64,
    pub(crate) last_level: f64,
    pub(crate) last_level_change_s: f64,
    pub(crate) report: AdaptReport,
}

impl AdaptLayer {
    /// Build the layer from the scenario's controller config, clamping
    /// the actuation range to what is physically racked: the controller
    /// can never activate servers the row does not have.
    pub(crate) fn new(a: &AdaptConfig, cfg: &SimConfig) -> AdaptLayer {
        let num = cfg.exp.row.num_servers.max(1);
        let deployed = cfg.deployed_servers.max(num);
        let racked_headroom = deployed as f64 / num as f64 - 1.0;
        let mut ctl_cfg = a.clone();
        ctl_cfg.max_added = ctl_cfg.max_added.min(racked_headroom);
        ctl_cfg.min_added = ctl_cfg.min_added.min(ctl_cfg.max_added);
        let ctl = AdaptController::new(ctl_cfg);
        let level = ctl.level();
        AdaptLayer {
            active_servers: active_for(num, deployed, level),
            ctl,
            num_servers: num,
            deployed,
            win_peak_norm: 0.0,
            win_hp_actual: 0.0,
            win_hp_nominal: 0.0,
            last_violation_s: 0.0,
            last_brakes: 0,
            level_time_acc: 0.0,
            last_level: level,
            last_level_change_s: 0.0,
            report: AdaptReport::default(),
        }
    }
}

/// How many of the deployed servers take traffic at a given added
/// level. Always at least the provisioned baseline, never more than
/// what is racked.
fn active_for(num: usize, deployed: usize, level: f64) -> usize {
    let want = (num as f64 * (1.0 + level)).round() as usize;
    want.clamp(num, deployed)
}

impl<'a, O: Observer> Sim<'a, O> {
    /// A retune window closes: assemble the window's feedback, ask the
    /// controller, actuate an `Apply`, and open the next window.
    pub(crate) fn on_retune_check(&mut self, now_s: f64) {
        // Bring the ground-truth violation integral current first, so
        // the window delta includes everything up to this boundary.
        self.settle_energy();
        let violation_total = self.acct.report.resilience.violation_s;
        let brakes_total = self.control.policy.brake_events;
        let cfg = self.cfg; // shared borrow, independent of `self`
        let ad = self.adapt.as_mut().expect("RetuneCheck without an adapt layer");
        let obs = WindowObs {
            violation_s: (violation_total - ad.last_violation_s).max(0.0),
            brakes: brakes_total.saturating_sub(ad.last_brakes),
            peak_norm: ad.win_peak_norm,
            hp_slowdown: if ad.win_hp_nominal > 0.0 {
                (ad.win_hp_actual / ad.win_hp_nominal - 1.0).max(0.0)
            } else {
                0.0
            },
        };
        let decision = ad.ctl.decide(now_s, &obs, &cfg.exp.slo);
        ad.report.evals += 1;
        ad.report.decisions.push(decision);
        // Open the next window.
        ad.win_peak_norm = 0.0;
        ad.win_hp_actual = 0.0;
        ad.win_hp_nominal = 0.0;
        ad.last_violation_s = violation_total;
        ad.last_brakes = brakes_total;
        match decision.verdict {
            Verdict::Hold => {
                if O::ENABLED {
                    self.obs.event(now_s, EventKind::RetuneEval { peak: obs.peak_norm });
                }
            }
            Verdict::Veto => {
                ad.report.vetoes += 1;
                if O::ENABLED {
                    self.obs.event(now_s, EventKind::RetuneVeto { added: decision.added });
                }
            }
            Verdict::Apply => {
                ad.report.applies += 1;
                ad.level_time_acc += (now_s - ad.last_level_change_s) * ad.last_level;
                ad.last_level = decision.added;
                ad.last_level_change_s = now_s;
                ad.active_servers = active_for(ad.num_servers, ad.deployed, decision.added);
                // Actuate the rung: the policy engine reads its config
                // on every tick, so writing T1/T2 takes effect at the
                // next telemetry sample.
                self.control.policy.cfg.t1 = decision.t1;
                self.control.policy.cfg.t2 = decision.t2;
                if O::ENABLED {
                    self.obs.event(
                        now_s,
                        EventKind::RetuneApply {
                            added: decision.added,
                            t1: decision.t1,
                            t2: decision.t2,
                        },
                    );
                }
                emit_diag(&DiagEvent::RetuneApplied {
                    t_s: now_s,
                    added: decision.added,
                    t1: decision.t1,
                    t2: decision.t2,
                });
            }
        }
        let window_s = self.adapt.as_ref().unwrap().ctl.cfg.window_s;
        if now_s + window_s < to_secs(self.core.horizon) {
            self.core.queue.schedule_at(secs(now_s + window_s), Ev::RetuneCheck);
        }
    }
}
