//! Unit tests for the layered simulator (the pre-split monolith's test
//! suite, kept verbatim so the decomposition is pinned by the exact
//! assertions the monolith carried; the cross-wiring bit-identity
//! goldens live in `tests/golden_simulation.rs`).

use super::*;
use crate::faults::{FaultKind, FaultPlan};
use crate::policy::engine::PolicyKind;

fn quick_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.weeks = 0.05; // ~8.4 hours
    cfg.deployed_servers = 12;
    cfg.exp.row.num_servers = 12;
    cfg.exp.seed = 42;
    // Small rows multiplex fewer prompt spikes, so their relative
    // variance is higher; calibrate the 12-server test row separately
    // (production rows are 40+, using DEFAULT_POWER_SCALE).
    cfg.power_scale = 1.35;
    cfg
}

#[test]
fn base_run_completes_requests_without_brakes() {
    let mut cfg = quick_cfg();
    cfg.weeks = 0.1;
    let report = run(&cfg);
    assert!(report.hp.completed > 50, "hp completed = {}", report.hp.completed);
    assert!(report.lp.completed > 50);
    assert_eq!(report.brake_events, 0);
    assert!(report.power_peak > 0.3 && report.power_peak < 1.0, "peak={}", report.power_peak);
}

#[test]
fn deterministic_given_seed() {
    let cfg = quick_cfg();
    let mut a = run(&cfg);
    let mut b = run(&cfg);
    assert_eq!(a.hp.completed, b.hp.completed);
    assert_eq!(a.lp.completed, b.lp.completed);
    assert_eq!(a.brake_events, b.brake_events);
    assert!((a.power_peak - b.power_peak).abs() < 1e-12);
    assert!((a.hp.latency.p99() - b.hp.latency.p99()).abs() < 1e-12);
}

#[test]
fn oversubscription_raises_power() {
    let base = run(&quick_cfg());
    let mut over_cfg = quick_cfg();
    over_cfg.deployed_servers = 16; // +33%
    let over = run(&over_cfg);
    assert!(over.power_mean > base.power_mean * 1.15,
        "base={} over={}", base.power_mean, over.power_mean);
}

#[test]
fn heavy_oversubscription_nocap_brakes_polca_does_not() {
    let mut nocap = quick_cfg();
    nocap.policy_kind = PolicyKind::NoCap;
    nocap.deployed_servers = 22; // +83%: pushes past the breaker
    nocap.weeks = 0.08;
    let r_nocap = run(&nocap);
    assert!(r_nocap.brake_events > 0, "no-cap at +83% must brake");

    let mut polca = nocap.clone();
    polca.policy_kind = PolicyKind::Polca;
    let r_polca = run(&polca);
    assert!(
        r_polca.brake_events <= r_nocap.brake_events,
        "POLCA ({}) must brake no more than No-cap ({})",
        r_polca.brake_events,
        r_nocap.brake_events
    );
    // POLCA's caps must push P99 power below No-cap's.
    assert!(r_polca.power_p99 <= r_nocap.power_p99 + 0.02);
}

#[test]
fn polca_caps_impact_lp_more_than_hp() {
    let mut cfg = quick_cfg();
    cfg.deployed_servers = 18; // +50%: capping definitely active
    cfg.weeks = 0.08;
    let (_, impact) = run_with_impact(&cfg);
    assert!(
        impact.lp_p99 >= impact.hp_p99 - 0.02,
        "LP p99 {} should be >= HP p99 {}",
        impact.lp_p99,
        impact.hp_p99
    );
}

#[test]
fn baseline_has_zero_impact_on_itself() {
    let cfg = quick_cfg().baseline();
    let (_, impact) = run_with_impact(&cfg);
    assert!(impact.hp_p50 < 1e-9 && impact.lp_p99 < 1e-9);
    assert_eq!(impact.brake_events, 0);
}

#[test]
fn no_oversubscription_meets_slo() {
    let mut cfg = quick_cfg();
    cfg.weeks = 0.08;
    let (_, impact) = run_with_impact(&cfg);
    assert!(
        impact.meets_slo(&cfg.exp.slo),
        "{:?}",
        impact.slo_violations(&cfg.exp.slo)
    );
}

#[test]
fn work_conservation_under_caps() {
    // Every arrival is eventually completed or dropped or in flight:
    // completed + dropped <= arrivals, and nothing is double counted.
    let mut cfg = quick_cfg();
    cfg.deployed_servers = 16;
    let report = run(&cfg);
    let total = report.hp.completed + report.lp.completed
        + report.hp.dropped + report.lp.dropped;
    assert!(total > 100);
    // All recorded latencies are >= nominal (impact >= 0) by metric
    // construction; peak power must never be absurd.
    assert!(report.power_peak < 2.0);
}

#[test]
fn mixed_zero_fraction_is_bit_identical_to_none() {
    let mut a_cfg = quick_cfg();
    a_cfg.weeks = 0.03;
    let mut b_cfg = a_cfg.clone();
    b_cfg.mixed = Some(MixedRowConfig::default()); // training_fraction 0.0
    let mut a = run(&a_cfg);
    let mut b = run(&b_cfg);
    assert_eq!(a.hp.completed, b.hp.completed);
    assert_eq!(a.lp.completed, b.lp.completed);
    assert_eq!(a.events, b.events);
    assert!((a.power_peak - b.power_peak).abs() == 0.0);
    assert!((a.hp.latency.p99() - b.hp.latency.p99()).abs() == 0.0);
    assert_eq!(b.train.iters, 0);
}

#[test]
fn pure_training_row_runs_iterations_at_tdp_class_power() {
    let mut cfg = quick_cfg();
    cfg.weeks = 0.01; // ~1.7 h
    cfg.policy_kind = PolicyKind::NoCap;
    cfg.mixed = Some(MixedRowConfig { training_fraction: 1.0, ..Default::default() });
    let report = run(&cfg);
    // No inference traffic at all on a pure-training row.
    assert_eq!(report.hp.completed + report.lp.completed, 0);
    assert!(report.train.iters > 500, "iters={}", report.train.iters);
    // §2.4: training sits just under provisioned power — far above
    // the inference mean — independent of the inference power_scale.
    assert!(
        report.power_peak > 0.85 && report.power_peak < 1.0,
        "peak={}",
        report.power_peak
    );
    // Uncapped iterations run at nominal speed (µs event rounding only).
    assert!(report.train.inflation() < 1e-4, "inflation={}", report.train.inflation());
    assert_eq!(report.brake_events, 0);
}

#[test]
fn polca_caps_training_and_inflates_iteration_time() {
    // A pure-training row idles above T2 (0.89), so POLCA must cap
    // it — and the cost shows up as iteration-time inflation, never
    // as request latency (§7: training is always cappable).
    let mut cfg = quick_cfg();
    cfg.weeks = 0.02;
    cfg.policy_kind = PolicyKind::Polca;
    cfg.mixed = Some(MixedRowConfig { training_fraction: 1.0, ..Default::default() });
    let report = run(&cfg);
    assert!(report.cap_commands > 0, "row above T2 must engage LP caps");
    assert!(
        report.train.inflation() > 0.005,
        "capped training must slow down: inflation={}",
        report.train.inflation()
    );
    assert_eq!(report.hp.completed, 0);
}

#[test]
fn training_fraction_interpolates_power_monotonically() {
    let mut peaks = Vec::new();
    for frac in [0.0, 0.5, 1.0] {
        let mut cfg = quick_cfg();
        cfg.weeks = 0.05;
        cfg.policy_kind = PolicyKind::NoCap;
        cfg.mixed = Some(MixedRowConfig { training_fraction: frac, ..Default::default() });
        peaks.push(run(&cfg).power_peak);
    }
    assert!(peaks[0] < peaks[1] && peaks[1] < peaks[2], "{peaks:?}");
}

#[test]
fn mixed_run_is_deterministic() {
    let mut cfg = quick_cfg();
    cfg.weeks = 0.02;
    cfg.mixed = Some(MixedRowConfig {
        training_fraction: 0.5,
        servers_per_job: 3,
        job_stagger_s: 2.0,
        ..Default::default()
    });
    let a = run(&cfg);
    let b = run(&cfg);
    assert_eq!(a.train.iters, b.train.iters);
    assert_eq!(a.hp.completed, b.hp.completed);
    assert!((a.power_peak - b.power_peak).abs() == 0.0);
    assert!((a.train.iter_time_sum_s - b.train.iter_time_sum_s).abs() == 0.0);
}

#[test]
fn empty_fault_plan_is_inert() {
    let mut a_cfg = quick_cfg();
    a_cfg.weeks = 0.03;
    let mut b_cfg = a_cfg.clone();
    b_cfg.faults = Some(FaultPlan::new());
    let a = run(&a_cfg);
    let b = run(&b_cfg);
    // Bit-identical, including the (empty) resilience accounting.
    assert_eq!(format!("{a:?}"), format!("{b:?}"));
    assert!(a.resilience.incidents.is_empty());
}

#[test]
fn feed_loss_is_contained_by_the_brake_path() {
    // Probe the clean run for its diurnal peak so the feed loss is
    // injected when it actually bites.
    let mut probe = quick_cfg();
    probe.weeks = 0.1;
    probe.policy_kind = PolicyKind::NoCap;
    probe.series_sample_s = 120.0;
    let horizon = probe.weeks * 7.0 * 86_400.0;
    let series = run(&probe).power_series;
    let &(t_peak, p_peak) = series
        .iter()
        .filter(|&&(t, _)| t < horizon - 7200.0)
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    // Cut the budget to well under the peak draw: the effective
    // reading crosses 1.0, and only the brake path can answer.
    let mut cfg = probe.clone();
    cfg.series_sample_s = 0.0;
    let window_s = 1800.0;
    let budget_frac = p_peak / 1.3;
    cfg.faults = Some(FaultPlan::new().with(
        FaultKind::FeedLoss { budget_frac },
        (t_peak - window_s / 2.0).max(0.0),
        window_s,
    ));
    let report = run(&cfg);
    assert_eq!(report.resilience.incidents.len(), 1);
    let inc = report.resilience.incidents[0].clone();
    assert!(report.resilience.violation_s > 0.0, "the cut must bite");
    assert!(inc.contained(), "{inc:?}");
    assert!(report.brake_commands > 0, "containment must have used the brake");
    // The brake (reported reading > 1.0 exactly when the effective
    // budget is violated) keeps the violation to a fraction of the
    // episode — the row is never left over budget for long.
    assert!(
        report.resilience.violation_s < 0.8 * window_s,
        "violation {}s over a {}s episode",
        report.resilience.violation_s,
        window_s
    );
    assert!(report.resilience.peak_overshoot_w > 0.0);
}

#[test]
fn full_telemetry_dropout_disables_the_control_loop() {
    let mut cfg = quick_cfg();
    cfg.weeks = 0.08;
    cfg.deployed_servers = 22; // heavy: the clean run would cap/brake
    let horizon = cfg.weeks * 7.0 * 86_400.0;
    cfg.faults = Some(FaultPlan::new().with(
        FaultKind::TelemetryFreeze,
        0.0,
        horizon + 1.0,
    ));
    let report = run(&cfg);
    // The policy never saw a reading: no caps, no brakes — and the
    // ground-truth accounting shows the row went over budget.
    assert_eq!(report.cap_commands, 0);
    assert_eq!(report.brake_commands, 0);
    assert!(report.resilience.violation_s > 0.0);
    assert!(report.resilience.true_peak_norm > 1.0);
}

#[test]
fn meter_bias_under_reports_the_peak() {
    let mut clean_cfg = quick_cfg();
    clean_cfg.weeks = 0.04;
    clean_cfg.policy_kind = PolicyKind::NoCap;
    let mut biased_cfg = clean_cfg.clone();
    let horizon = biased_cfg.weeks * 7.0 * 86_400.0;
    biased_cfg.faults = Some(FaultPlan::new().with(
        FaultKind::MeterBias { mult: 0.5 },
        0.0,
        horizon + 1.0,
    ));
    let clean = run(&clean_cfg);
    let biased = run(&biased_cfg);
    // Reported statistics shrink with the bias; the ground truth
    // does not move (same workload, same NoCap policy).
    assert!((biased.power_peak - 0.5 * clean.power_peak).abs() < 1e-9);
    assert!(
        (biased.resilience.true_peak_norm - clean.resilience.true_peak_norm).abs() < 1e-12
    );
}

#[test]
fn oob_loss_storm_triggers_reissue_not_silence() {
    let mut cfg = quick_cfg();
    cfg.weeks = 0.08;
    cfg.deployed_servers = 18; // capping definitely intended
    let horizon = cfg.weeks * 7.0 * 86_400.0;
    cfg.faults = Some(FaultPlan::new().with(
        FaultKind::OobStorm { loss_prob: 1.0, latency_mult: 1.0, jitter_frac: 0.0 },
        0.0,
        horizon + 1.0,
    ));
    let report = run(&cfg);
    // Every slow-path command is lost, so none applies — but the
    // rack manager keeps retrying after the apply timeout.
    assert_eq!(report.cap_commands, 0);
    assert!(report.resilience.reissued_commands > 0);
}

#[test]
fn calibration_hits_target_peak() {
    let mut cfg = SimConfig::default();
    cfg.weeks = 0.15;
    cfg.deployed_servers = 40;
    cfg.policy_kind = PolicyKind::NoCap;
    cfg.exp.seed = 7;
    let report = run(&cfg);
    // With the shipped DEFAULT_POWER_SCALE the base row should peak
    // near the Table-2 inference utilization.
    assert!(
        (0.70..=0.88).contains(&report.power_peak),
        "peak={} (rescale DEFAULT_POWER_SCALE?)",
        report.power_peak
    );
}
