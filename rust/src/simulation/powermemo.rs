//! Exact-input memoization of per-server power evaluation.
//!
//! `Sim::refresh_power` runs on every request phase transition, cap
//! change, and training waveform step — the single hottest call site in
//! a run — and each evaluation walks the server model's component table
//! and (under a frequency cap) a `powf` frequency/power curve. The
//! insight making a cache *exact* rather than approximate: the input
//! alphabet is tiny. Prompt `total_input` values are integers (the
//! workload sampler rounds its log-uniform draws), token batch is
//! always 1.0 in the one-request-per-server serving model, training
//! waveform levels come from a four-phase profile, and the cap state is
//! one of a handful of policy-rung frequencies. A whole one-day run
//! evaluates only a few hundred *distinct* (phase, cap) pairs across
//! millions of refreshes.
//!
//! Bit-identity is preserved by construction: keys are the exact input
//! bits ([`f64::to_bits`]), values are produced by the exact same code
//! path ([`ServerPowerModel::server_power_w`] /
//! [`ServerPowerModel::training_power_w`]) on first sight, and f64
//! arithmetic is deterministic — a cache hit returns the identical bits
//! a recomputation would. No reassociation, no approximation, nothing
//! for `tests/golden_simulation.rs` to notice.
//!
//! The table is keyed with the in-tree [`FxBuildHasher`] (a SipHash
//! lookup would cost a good fraction of the evaluation it replaces) and
//! is per-run state inside the server layer — no locks, no global.

use std::collections::HashMap;

use crate::power::gpu::{CapMode, Phase};
use crate::power::server::ServerPowerModel;
use crate::util::hash::FxBuildHasher;

/// Phase-class discriminants of the memo key (the `u8` tag).
const TAG_IDLE: u8 = 0;
const TAG_TOKEN: u8 = 1;
const TAG_PROMPT: u8 = 2;
const TAG_TRAIN: u8 = 3;

/// Cap-state encoding: `CapMode::None` maps to a sentinel that is a NaN
/// bit pattern, unreachable by any real `mhz` value's `to_bits()`.
const CAP_NONE_BITS: u64 = u64::MAX;

/// Exact-input memo over `(phase-class, phase-param bits, cap bits)`.
pub(crate) struct PowerMemo {
    table: HashMap<(u8, u64, u64), f64, FxBuildHasher>,
}

impl PowerMemo {
    pub(crate) fn new() -> PowerMemo {
        PowerMemo { table: HashMap::default() }
    }

    /// Distinct (phase, cap) pairs evaluated so far (diagnostics/tests).
    #[cfg(test)]
    pub(crate) fn distinct_inputs(&self) -> usize {
        self.table.len()
    }

    /// Memoized [`ServerPowerModel::server_power_w`] for the simulator's
    /// inference path (which always passes `spike_escaping = false`).
    /// `CapMode::PowerCap` — never produced by `Sim::cap_mode` — bypasses
    /// the table defensively rather than widening the key.
    #[inline]
    pub(crate) fn inference_w(
        &mut self,
        model: &ServerPowerModel,
        phase: Phase,
        cap: CapMode,
    ) -> f64 {
        let (tag, phase_bits) = match phase {
            Phase::Idle => (TAG_IDLE, 0u64),
            Phase::Token { batch } => (TAG_TOKEN, batch.to_bits()),
            Phase::Prompt { total_input } => (TAG_PROMPT, total_input.to_bits()),
        };
        let cap_bits = match cap {
            CapMode::None => CAP_NONE_BITS,
            CapMode::FreqCap { mhz } => mhz.to_bits(),
            CapMode::PowerCap { .. } => return model.server_power_w(phase, cap, false),
        };
        *self
            .table
            .entry((tag, phase_bits, cap_bits))
            .or_insert_with(|| model.server_power_w(phase, cap, false))
    }

    /// Memoized training-server wall power: the job's nominal waveform
    /// level under a cap, through the same
    /// `capped_level` → [`ServerPowerModel::training_power_w`] pipeline
    /// the un-memoized path ran (bit-identical on hit and miss alike).
    #[inline]
    pub(crate) fn training_w(
        &mut self,
        model: &ServerPowerModel,
        nominal_level: f64,
        cap: CapMode,
    ) -> f64 {
        let cap_bits = match cap {
            CapMode::None => CAP_NONE_BITS,
            CapMode::FreqCap { mhz } => mhz.to_bits(),
            CapMode::PowerCap { .. } => {
                let frac = model.calib.capped_level(nominal_level, cap);
                return model.training_power_w(frac);
            }
        };
        *self.table.entry((TAG_TRAIN, nominal_level.to_bits(), cap_bits)).or_insert_with(|| {
            let frac = model.calib.capped_level(nominal_level, cap);
            model.training_power_w(frac)
        })
    }
}

impl std::fmt::Debug for PowerMemo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PowerMemo").field("distinct_inputs", &self.table.len()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs() -> Vec<(Phase, CapMode)> {
        let phases = vec![
            Phase::Idle,
            Phase::Token { batch: 1.0 },
            Phase::Prompt { total_input: 256.0 },
            Phase::Prompt { total_input: 1024.0 },
            Phase::Prompt { total_input: 8192.0 },
        ];
        let caps = vec![
            CapMode::None,
            CapMode::FreqCap { mhz: 1110.0 },
            CapMode::FreqCap { mhz: 1290.0 },
        ];
        phases
            .iter()
            .flat_map(|&p| caps.iter().map(move |&c| (p, c)))
            .collect()
    }

    #[test]
    fn memo_is_bit_identical_to_direct_eval() {
        let model = ServerPowerModel::default();
        let mut memo = PowerMemo::new();
        for (phase, cap) in inputs() {
            let direct = model.server_power_w(phase, cap, false);
            // Miss, then hit: both must be the exact bits of `direct`.
            let miss = memo.inference_w(&model, phase, cap);
            let hit = memo.inference_w(&model, phase, cap);
            assert_eq!(miss.to_bits(), direct.to_bits());
            assert_eq!(hit.to_bits(), direct.to_bits());
        }
    }

    #[test]
    fn one_entry_per_distinct_input() {
        let model = ServerPowerModel::default();
        let mut memo = PowerMemo::new();
        let ins = inputs();
        for _ in 0..10 {
            for &(phase, cap) in &ins {
                memo.inference_w(&model, phase, cap);
            }
        }
        assert_eq!(memo.distinct_inputs(), ins.len());
    }

    #[test]
    fn training_path_matches_direct_pipeline() {
        let model = ServerPowerModel::default();
        let mut memo = PowerMemo::new();
        for &level in &[model.calib.idle_frac, 0.5, 0.88, 1.05] {
            for &cap in &[CapMode::None, CapMode::FreqCap { mhz: 1110.0 }] {
                let frac = model.calib.capped_level(level, cap);
                let direct = model.training_power_w(frac);
                assert_eq!(memo.training_w(&model, level, cap).to_bits(), direct.to_bits());
                assert_eq!(memo.training_w(&model, level, cap).to_bits(), direct.to_bits());
            }
        }
    }

    #[test]
    fn power_cap_bypasses_the_table() {
        let model = ServerPowerModel::default();
        let mut memo = PowerMemo::new();
        let phase = Phase::Prompt { total_input: 4096.0 };
        let cap = CapMode::PowerCap { frac_of_tdp: 0.8 };
        let direct = model.server_power_w(phase, cap, false);
        assert_eq!(memo.inference_w(&model, phase, cap).to_bits(), direct.to_bits());
        assert_eq!(memo.distinct_inputs(), 0, "PowerCap must not populate the memo");
    }

    #[test]
    fn cap_none_sentinel_cannot_collide_with_a_real_frequency() {
        // The sentinel is a NaN bit pattern; `to_bits` of any real mhz
        // (finite, positive) can never equal it.
        assert!(f64::from_bits(CAP_NONE_BITS).is_nan());
        for mhz in [210.0_f64, 990.0, 1110.0, 1410.0] {
            assert_ne!(mhz.to_bits(), CAP_NONE_BITS);
        }
    }
}
