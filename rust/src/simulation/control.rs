//! Control layer: telemetry → policy engine → OOB actuation.
//!
//! Owns the closed loop the paper builds in §4/§5: the PDU
//! [`TelemetryBuffer`] (2 s visibility delay, window-averaged meter
//! readings), the [`PolicyEngine`] (Algorithm 1 and the baselines), the
//! [`OobChannel`] with the Table-1 latencies (slow-path frequency caps
//! ~40 s, fast-path powerbrake ~5 s), and the rack manager's delivery
//! state — last *acknowledged* cap per priority class plus the re-issue
//! clocks behind the idempotent retry loop (`Sim::reconcile_oob`).
//!
//! The row-wide powerbrake lives here too (`Sim::set_brake`): it is
//! control-plane actuation (a BMC hardware signal), even though its
//! effect fans out across every server in [`super::servers`].

use crate::cluster::hierarchy::Priority;
use crate::cluster::oob::{OobChannel, OobCommand};
use crate::cluster::telemetry::TelemetryBuffer;
use crate::obs::{EventKind, Observer, SeriesId};
use crate::policy::engine::{Action, PolicyEngine};
use crate::sim::secs;

use super::core::{Ev, Sim};
use super::SimConfig;

/// Whether a slow-path command addresses the given priority class.
pub(crate) fn targets(cmd: &OobCommand, p: Priority) -> bool {
    match cmd {
        OobCommand::FreqCap { target, .. } | OobCommand::Uncap { target } => *target == p,
        OobCommand::PowerBrake | OobCommand::ReleaseBrake => false,
    }
}

/// Telemetry, policy, OOB transport, and rack-manager delivery state.
pub(crate) struct ControlLayer {
    pub(crate) policy: PolicyEngine,
    pub(crate) oob: OobChannel,
    pub(crate) telemetry: TelemetryBuffer,
    pub(crate) braked: bool,
    pub(crate) brake_engaged_at: f64,
    /// Last slow-path cap state *acknowledged* per priority class (what
    /// the rack manager believes is applied; cap-ignoring servers ack
    /// without applying, so reconciliation cannot see them).
    pub(crate) acked_lp: Option<f64>,
    pub(crate) acked_hp: Option<f64>,
    /// Last attempt times per class, for the re-issue timeout.
    pub(crate) lp_last_issue_s: f64,
    pub(crate) hp_last_issue_s: f64,
}

impl ControlLayer {
    pub(crate) fn new(cfg: &SimConfig) -> ControlLayer {
        let mut policy = PolicyEngine::new(cfg.policy_kind, cfg.exp.policy.clone());
        policy.escalate_to_brake_after_s = cfg.brake_escalation_s;
        let oob = OobChannel::new(
            cfg.exp.row.oob_latency_s,
            cfg.exp.row.power_brake_latency_s,
            cfg.exp.seed ^ 0xBEEF,
        )
        .with_unreliability(cfg.oob_loss_prob, cfg.oob_jitter_frac);
        let telemetry = TelemetryBuffer::new(
            cfg.exp.row.telemetry_delay_s,
            cfg.weeks * 7.0 * 86_400.0 + 1.0, // retain everything for Table 2 stats
        );
        ControlLayer {
            policy,
            oob,
            telemetry,
            braked: false,
            brake_engaged_at: 0.0,
            acked_lp: None,
            acked_hp: None,
            lp_last_issue_s: f64::NEG_INFINITY,
            hp_last_issue_s: f64::NEG_INFINITY,
        }
    }
}

impl<'a, O: Observer> Sim<'a, O> {
    pub(crate) fn set_brake(&mut self, on: bool, now_s: f64) {
        if self.control.braked == on {
            return;
        }
        if O::ENABLED {
            let kind = if on { EventKind::BrakeEngaged } else { EventKind::BrakeReleased };
            self.obs.event(now_s, kind);
        }
        // Advance all running work at the old ratios first.
        for idx in 0..self.servers.n_servers() {
            self.advance_work(idx, now_s);
        }
        self.control.braked = on;
        if on {
            self.control.brake_engaged_at = now_s;
        } else {
            self.acct.report.brake_time_s += now_s - self.control.brake_engaged_at;
        }
        // Row-wide actuation sweep: the gen bump walks one contiguous
        // hot vector (the SoA payoff), then each server re-settles.
        for g in &mut self.servers.gen {
            *g = g.wrapping_add(1);
        }
        for idx in 0..self.servers.n_servers() {
            self.refresh_power(idx);
            self.schedule_phase_end(idx, now_s);
        }
    }

    pub(crate) fn on_telemetry(&mut self, now_s: f64) {
        self.core.queue.schedule_in(secs(self.cfg.exp.row.telemetry_period_s), Ev::Telemetry);
        let p = self.averaged_row_power();
        if now_s == 0.0 {
            return; // no averaging window yet — first real sample comes next tick
        }
        self.control.telemetry.record(now_s, p);
        if let Some(ad) = self.adapt.as_mut() {
            ad.win_peak_norm = ad.win_peak_norm.max(p);
        }
        if O::ENABLED {
            self.obs.event(now_s, EventKind::Telemetry { reported: p });
            let true_p = self.normalized_row_power();
            let budget_mult = self.faults.budget_mult;
            let queued = self.servers.cold.iter().filter(|c| c.queued.is_some()).count();
            let caps = if self.control.braked {
                self.servers.n_servers()
            } else {
                self.servers.freq_cap_mhz.iter().filter(|c| c.is_some()).count()
            };
            self.obs.sample(SeriesId::RowPower, now_s, true_p);
            self.obs.sample(SeriesId::ReportedPower, now_s, p);
            self.obs.sample(SeriesId::BudgetFrac, now_s, budget_mult);
            self.obs.sample(SeriesId::QueueDepth, now_s, queued as f64);
            self.obs.sample(SeriesId::ActiveCaps, now_s, caps as f64);
        }
        if !self.cfg.protection {
            return;
        }
        let Some((_, visible)) = self.control.telemetry.visible_at(now_s) else {
            return;
        };
        let actions = self.control.policy.tick(now_s, visible);
        for act in actions {
            let cmd = match act {
                Action::CapLp { mhz } => OobCommand::FreqCap { target: Priority::Low, mhz },
                Action::CapHp { mhz } => OobCommand::FreqCap { target: Priority::High, mhz },
                Action::UncapLp => OobCommand::Uncap { target: Priority::Low },
                Action::UncapHp => OobCommand::Uncap { target: Priority::High },
                Action::Brake => OobCommand::PowerBrake,
                Action::ReleaseBrake => OobCommand::ReleaseBrake,
            };
            self.issue_cmd(now_s, cmd);
        }
        self.reconcile_oob(now_s);
    }

    /// Issue one command through the OOB channel, recording the attempt
    /// time per class (the re-issue timeout clock).
    pub(crate) fn issue_cmd(&mut self, now_s: f64, cmd: OobCommand) {
        if O::ENABLED {
            let kind = match cmd {
                OobCommand::FreqCap { target, mhz } => {
                    EventKind::CapIssued { class: target, mhz }
                }
                OobCommand::Uncap { target } => EventKind::UncapIssued { class: target },
                OobCommand::PowerBrake => EventKind::BrakeIssued,
                OobCommand::ReleaseBrake => EventKind::BrakeReleaseIssued,
            };
            self.obs.event(now_s, kind);
        }
        match cmd {
            OobCommand::FreqCap { target: Priority::Low, .. }
            | OobCommand::Uncap { target: Priority::Low } => self.control.lp_last_issue_s = now_s,
            OobCommand::FreqCap { target: Priority::High, .. }
            | OobCommand::Uncap { target: Priority::High } => self.control.hp_last_issue_s = now_s,
            OobCommand::PowerBrake | OobCommand::ReleaseBrake => {}
        }
        if let Some(apply_at) = self.control.oob.issue(now_s, cmd) {
            self.core.queue.schedule_at(secs(apply_at), Ev::OobApply);
        }
    }

    /// Re-issue slow-path commands that were *lost* (never acknowledged)
    /// once the apply timeout has elapsed — the idempotent-retry loop a
    /// real rack manager runs over SMBPBI. Commands that were
    /// acknowledged are never re-issued, so a cap-ignoring server (acks,
    /// does not apply) is invisible here; containing it is the policy
    /// engine's escalation job, not the transport's.
    pub(crate) fn reconcile_oob(&mut self, now_s: f64) {
        let timeout = self.cfg.exp.row.oob_latency_s * 1.5 + self.cfg.exp.row.telemetry_period_s;
        let intent = self.control.policy.intent();
        if intent.lp_cap_mhz != self.control.acked_lp
            && now_s - self.control.lp_last_issue_s > timeout
            && !self.control.oob.has_pending(|c| targets(c, Priority::Low))
        {
            self.acct.report.resilience.reissued_commands += 1;
            if O::ENABLED {
                self.obs.event(
                    now_s,
                    EventKind::CapReissued { class: Priority::Low, mhz: intent.lp_cap_mhz },
                );
            }
            let cmd = match intent.lp_cap_mhz {
                Some(mhz) => OobCommand::FreqCap { target: Priority::Low, mhz },
                None => OobCommand::Uncap { target: Priority::Low },
            };
            self.issue_cmd(now_s, cmd);
        }
        if intent.hp_cap_mhz != self.control.acked_hp
            && now_s - self.control.hp_last_issue_s > timeout
            && !self.control.oob.has_pending(|c| targets(c, Priority::High))
        {
            self.acct.report.resilience.reissued_commands += 1;
            if O::ENABLED {
                self.obs.event(
                    now_s,
                    EventKind::CapReissued { class: Priority::High, mhz: intent.hp_cap_mhz },
                );
            }
            let cmd = match intent.hp_cap_mhz {
                Some(mhz) => OobCommand::FreqCap { target: Priority::High, mhz },
                None => OobCommand::Uncap { target: Priority::High },
            };
            self.issue_cmd(now_s, cmd);
        }
    }

    pub(crate) fn on_oob_apply(&mut self, now_s: f64) {
        for pending in self.control.oob.due(now_s) {
            match pending.cmd {
                OobCommand::FreqCap { target, mhz } => {
                    self.acct.report.cap_commands += 1;
                    self.ack(target, Some(mhz));
                    if O::ENABLED {
                        self.obs.event(now_s, EventKind::CapAcked { class: target, mhz });
                    }
                    for idx in 0..self.servers.n_servers() {
                        // Cap-ignoring servers acknowledge (the ack is
                        // recorded above) but do not change frequency.
                        if self.servers.priority[idx] == target && !self.faults.cap_ignore[idx] {
                            self.set_server_cap(idx, Some(mhz), now_s);
                        }
                    }
                }
                OobCommand::Uncap { target } => {
                    self.acct.report.uncap_commands += 1;
                    self.ack(target, None);
                    if O::ENABLED {
                        self.obs.event(now_s, EventKind::UncapAcked { class: target });
                    }
                    for idx in 0..self.servers.n_servers() {
                        if self.servers.priority[idx] == target && !self.faults.cap_ignore[idx] {
                            self.set_server_cap(idx, None, now_s);
                        }
                    }
                }
                // The brake is a hardware signal below the wedged
                // firmware: cap-ignoring servers obey it too.
                OobCommand::PowerBrake => {
                    self.acct.report.brake_commands += 1;
                    self.set_brake(true, now_s);
                }
                OobCommand::ReleaseBrake => self.set_brake(false, now_s),
            }
        }
    }

    /// Record a delivered (acknowledged) slow-path cap state per class.
    pub(crate) fn ack(&mut self, target: Priority, cap: Option<f64>) {
        match target {
            Priority::Low => self.control.acked_lp = cap,
            Priority::High => self.control.acked_hp = cap,
        }
    }
}
