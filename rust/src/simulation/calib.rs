//! Row-power calibration: fitting `power_scale` and the memoized
//! per-row-size cache behind [`power_scale_for_row`].
//!
//! The analytic single-request server model understates the sustained
//! draw of production serving (continuous batching, co-located
//! services), so a scalar `power_scale` is fitted once so the *base*
//! row (no oversubscription, no capping) peaks at the published
//! Table-2 inference utilization (79%) — the same trace-replication
//! step the paper performs in §6.1. Small rows multiplex fewer prompt
//! spikes, so their relative variance is higher and the fitted scale
//! is smaller; the fit is therefore keyed by the row's baseline server
//! count.
//!
//! Fitting means running a full calibration simulation, and sweep
//! loops (fleet planning, the fault matrix, scenario batches) ask for
//! the same row sizes over and over — so the fits live in a small
//! seeded cache: the three row sizes every in-tree surface uses (40,
//! 16, 12) are pre-seeded with the pinned published fits (keeping
//! every existing output bit-identical and free), and any novel size
//! triggers exactly one deterministic calibration run, memoized for
//! the rest of the process ([`calibration_runs`] counts them; a unit
//! test pins "one calibration per distinct row size").
//!
//! Deliberate behavior change vs the pre-ISSUE-5 band table (which
//! mapped *every* size to one of the three constants): a non-anchor
//! size like 20 now gets a real fit instead of borrowing the
//! 16-server constant. The first lookup announces itself through the
//! [`crate::obs`] diagnostic hook (quiet by default for library
//! embedders; the CLI installs a stderr printer) and costs one one-day
//! simulation; an explicit `power_scale` on the scenario/config
//! bypasses the fit entirely.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::characterize::catalog::ModelSpec;
use crate::policy::engine::PolicyKind;
use crate::util::rng::Rng;
use crate::workload::spec::{sample_request, WorkloadSpec};

use super::{run, SimConfig};

/// Fitted once via [`calibrate`] with the default config; pins the base
/// 40-server row's diurnal peak at the Table-2 inference utilization
/// (≈0.79).
pub const DEFAULT_POWER_SCALE: f64 = 1.74;

/// The Table-2 inference peak every row-size fit targets.
const CALIB_TARGET_PEAK: f64 = 0.79;
/// Horizon of one calibration run: one simulated day — exactly one
/// full diurnal cycle, so the peak is observed at the lowest cost.
const CALIB_WEEKS: f64 = 1.0 / 7.0;
/// Fixed seed of the calibration workload realization — the cache is
/// *seeded*: a given row size always fits the same scale, in any
/// process, on any thread.
const CALIB_SEED: u64 = 0xCA11_B5EE_D;

/// How many calibration simulations this process has run (cache
/// misses). Pre-seeded fits and repeated lookups never increment it —
/// the memoization test pins exactly one run per distinct row size.
static CALIBRATION_RUNS: AtomicUsize = AtomicUsize::new(0);

/// Calibration simulations run so far in this process (a diagnostics /
/// test hook for the [`power_scale_for_row`] memo cache).
pub fn calibration_runs() -> usize {
    CALIBRATION_RUNS.load(Ordering::SeqCst)
}

/// Test hook: the cached fit for a row size, if any. A present key can
/// never be re-fit (fits happen only on a miss, under the cache lock),
/// which is what the memoization test asserts on — immune to other
/// tests concurrently fitting *other* sizes.
#[cfg(test)]
fn cached_fit(baseline_servers: usize) -> Option<f64> {
    cache().lock().expect("calibration cache poisoned").get(&baseline_servers).copied()
}

fn cache() -> &'static Mutex<HashMap<usize, f64>> {
    static CACHE: OnceLock<Mutex<HashMap<usize, f64>>> = OnceLock::new();
    CACHE.get_or_init(|| {
        // The pinned fits, produced by the same procedure as
        // `fit_power_scale` and frozen so the paper row (40), the
        // fleet/matrix rows (16), and the quick-test rows (12) stay
        // bit-identical across releases without re-running the fit.
        Mutex::new(HashMap::from([(40, DEFAULT_POWER_SCALE), (16, 1.45), (12, 1.35)]))
    })
}

/// One full calibration simulation for a row of `baseline_servers`:
/// the base row (no oversubscription), power manager disconnected,
/// `power_scale = 1.0`; the fitted scale is the ratio that would have
/// pinned the observed peak at the Table-2 target.
fn fit_power_scale(baseline_servers: usize) -> f64 {
    CALIBRATION_RUNS.fetch_add(1, Ordering::SeqCst);
    // Announce the one-time cost: this is a full one-day simulation,
    // not a table lookup, and a CLI user who picked a novel row size
    // deserves to know why the first run pauses (set an explicit
    // `power_scale` in the scenario to skip the fit entirely). The
    // notice goes through the quiet-by-default diagnostic hook so
    // library embedders are never spammed on stderr; `polca`'s main()
    // installs the printer.
    crate::obs::emit_diag(&crate::obs::DiagEvent::CalibrationFit { baseline_servers });
    let mut cfg = SimConfig {
        policy_kind: PolicyKind::NoCap,
        deployed_servers: baseline_servers,
        weeks: CALIB_WEEKS,
        power_scale: 1.0,
        ..Default::default()
    };
    cfg.exp.row.num_servers = baseline_servers;
    cfg.exp.seed = CALIB_SEED;
    let report = run(&cfg);
    if report.power_peak > 0.0 {
        CALIB_TARGET_PEAK / report.power_peak
    } else {
        DEFAULT_POWER_SCALE // degenerate row (no load observed): keep the default fit
    }
}

/// The row-size-appropriate power calibration, memoized: pre-seeded
/// pinned fits for the standard row sizes, one deterministic
/// calibration simulation (then cached) for any other size. Shared by
/// the scenario layer, the fleet layer, and the fault matrix so every
/// surface calibrates identically.
pub fn power_scale_for_row(baseline_servers: usize) -> f64 {
    let mut cache = cache().lock().expect("calibration cache poisoned");
    if let Some(&scale) = cache.get(&baseline_servers) {
        return scale;
    }
    // Deliberately fitted under the lock: concurrent first lookups of
    // one novel size must still produce exactly one calibration run.
    let scale = fit_power_scale(baseline_servers);
    cache.insert(baseline_servers, scale);
    scale
}

// ---- mean-service estimation cache (ISSUE 10) --------------------------
//
// `ServerLayer::new` derives per-workload arrival rates from a
// 400-sample Monte Carlo estimate of each workload's nominal service
// time. That estimate re-ran on every one of the thousands of Sim
// constructions in a sweep — despite being fully determined by the
// estimation stream's seed and the latency-relevant model knobs. It is
// memoized here, beside the power-scale cache, with the same contract:
// one deterministic estimation per distinct key, counted for the unit
// test.
//
// Key design note: ISSUE 10 asks for the (model_name, perf_mult,
// workload_power_mult) triple; the key here is that triple *plus the
// estimation stream's seed*. The seed is required for bit-identity —
// the stream is forked from the config-seeded root RNG *after* the
// workload assignment shuffle, so it varies with `exp.seed` and with
// the deployed-server count, and collapsing distinct seeds onto one
// triple would change every existing trace. The triple alone would
// also be unsound for correctness, not just identity: the estimate's
// value genuinely depends on the sample stream.

/// Mean-service memo key: (estimation-stream seed, model name,
/// `perf_mult` bits, `workload_power_mult` bits).
type MeanServiceKey = (u64, String, u64, u64);

/// How many mean-service Monte Carlo estimations this process has run
/// (cache misses). Repeated constructions at the same key never
/// increment it.
static MEAN_SERVICE_ESTIMATIONS: AtomicUsize = AtomicUsize::new(0);

/// Mean-service estimations run so far in this process (a diagnostics /
/// test hook for the crate-internal `mean_service_for` memo cache,
/// mirroring [`calibration_runs`]).
pub fn mean_service_estimations() -> usize {
    MEAN_SERVICE_ESTIMATIONS.load(Ordering::SeqCst)
}

/// Test hook: the cached estimate for a key, if any (same contract as
/// [`cached_fit`]: a present key can never be re-estimated).
#[cfg(test)]
fn cached_mean_service(key: &MeanServiceKey) -> Option<Vec<f64>> {
    mean_service_cache().lock().expect("mean-service cache poisoned").get(key).cloned()
}

fn mean_service_cache() -> &'static Mutex<HashMap<MeanServiceKey, Vec<f64>>> {
    static CACHE: OnceLock<Mutex<HashMap<MeanServiceKey, Vec<f64>>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Per-workload mean nominal service times, memoized. On a miss this
/// runs the exact pre-memo estimation loop — one `Rng::new(est_seed)`
/// stream threaded across every workload spec in order, 400 samples
/// each — so hit and miss return bit-identical vectors.
///
/// `model` must already carry the `perf_mult` / `workload_power_mult`
/// knob adjustments the key names (it does: `ServerLayer::new` applies
/// them before calling here), and `specs` is the fixed Table-4 set.
pub(crate) fn mean_service_for(
    est_seed: u64,
    model_name: &str,
    perf_mult: f64,
    workload_power_mult: f64,
    model: &ModelSpec,
    specs: &[WorkloadSpec],
) -> Vec<f64> {
    let key: MeanServiceKey =
        (est_seed, model_name.to_string(), perf_mult.to_bits(), workload_power_mult.to_bits());
    let mut cache = mean_service_cache().lock().expect("mean-service cache poisoned");
    if let Some(v) = cache.get(&key) {
        return v.clone();
    }
    // Estimated under the lock, like the power-scale fit: concurrent
    // first constructions at one key must produce exactly one
    // estimation.
    MEAN_SERVICE_ESTIMATIONS.fetch_add(1, Ordering::SeqCst);
    let mut est_rng = Rng::new(est_seed);
    let mut mean_service: Vec<f64> = Vec::with_capacity(specs.len());
    for spec in specs {
        let mut acc = 0.0;
        let n = 400;
        for _ in 0..n {
            let (i, o) = sample_request(spec, &mut est_rng);
            acc += model.request_latency_s(i, o, 1.0, 1.0);
        }
        mean_service.push(acc / n as f64);
    }
    cache.insert(key, mean_service.clone());
    mean_service
}

/// Fit `power_scale` so the base row (baseline servers, no capping)
/// peaks at `target_peak` (Table 2 inference: 0.79). Returns the scale.
pub fn calibrate(target_peak: f64, weeks: f64, seed: u64) -> f64 {
    let mut cfg = SimConfig {
        policy_kind: PolicyKind::NoCap,
        weeks,
        power_scale: 1.0,
        ..Default::default()
    };
    cfg.exp.seed = seed;
    let report = run(&cfg);
    target_peak / report.power_peak
}

/// The telemetry-visible power series of a run (for trace MAPE checks).
pub fn power_series_of(cfg: &SimConfig) -> Vec<(f64, f64)> {
    let mut c = cfg.clone();
    c.series_sample_s = if c.series_sample_s > 0.0 { c.series_sample_s } else { 60.0 };
    run(&c).power_series
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pinned_fits_cost_no_calibration_run() {
        let before = calibration_runs();
        assert_eq!(power_scale_for_row(40), DEFAULT_POWER_SCALE);
        assert_eq!(power_scale_for_row(16), 1.45);
        assert_eq!(power_scale_for_row(12), 1.35);
        // Other tests may calibrate novel sizes concurrently, so assert
        // on this thread's contribution only: the pinned lookups above
        // never fit.
        assert!(
            calibration_runs() >= before,
            "counter is monotone: {before} -> {}",
            calibration_runs()
        );
        assert_eq!(power_scale_for_row(40), DEFAULT_POWER_SCALE, "lookup is idempotent");
    }

    #[test]
    fn novel_row_size_calibrates_exactly_once() {
        // 11 servers is used by no other surface or test, so this test
        // owns the key — assertions are on the per-key cache state, not
        // on exact global-counter deltas (other tests may legitimately
        // fit *other* sizes concurrently).
        assert!(cached_fit(11).is_none(), "size 11 must be novel to this test binary");
        let before = calibration_runs();
        let first = power_scale_for_row(11);
        assert!(calibration_runs() > before, "a novel size must run a calibration");
        assert_eq!(cached_fit(11), Some(first), "the fit is memoized under its key");
        // Fits happen only on a cache miss, under the cache lock, so a
        // present key can never be re-fit: this lookup is a pure hit.
        let second = power_scale_for_row(11);
        assert_eq!(first, second, "memoized fit must be stable");
        assert_eq!(cached_fit(11), Some(first));
        // A small row multiplexes fewer spikes than the 40-server row,
        // so its fitted scale is materially smaller than the default —
        // and any fit far outside the published band is a regression.
        assert!(
            (0.8..=DEFAULT_POWER_SCALE).contains(&first),
            "11-server fit {first} outside the plausible band"
        );
    }

    #[test]
    fn mean_service_estimates_exactly_once_per_distinct_key() {
        let model = crate::characterize::catalog::find("BLOOM-176B").expect("catalog model");
        let specs = crate::workload::spec::table4();
        // A seed no simulation construction can collide with: real keys
        // come from `fork_seed` on a config-seeded stream, while this
        // test owns its literal.
        let est_seed = 0xDEAD_10CC_u64;
        let key: MeanServiceKey =
            (est_seed, "BLOOM-176B".to_string(), 1.0f64.to_bits(), 1.0f64.to_bits());
        assert!(cached_mean_service(&key).is_none(), "key must be novel to this test binary");
        let before = mean_service_estimations();
        let first = mean_service_for(est_seed, "BLOOM-176B", 1.0, 1.0, &model, &specs);
        assert!(mean_service_estimations() > before, "a novel key must run an estimation");
        assert_eq!(cached_mean_service(&key), Some(first.clone()), "estimate memoized under key");
        // Estimations happen only on a miss, under the cache lock, so a
        // present key can never be re-estimated: this lookup is a hit.
        let second = mean_service_for(est_seed, "BLOOM-176B", 1.0, 1.0, &model, &specs);
        assert_eq!(first, second, "memoized estimate must be bit-stable");
        assert_eq!(first.len(), specs.len(), "one mean per workload spec");
        assert!(first.iter().all(|&m| m > 0.0), "service times are positive: {first:?}");
        // A different seed is a different key: a second estimation runs
        // and its result differs (different sample realization).
        let other = mean_service_for(est_seed ^ 1, "BLOOM-176B", 1.0, 1.0, &model, &specs);
        assert_ne!(first, other, "distinct sample streams give distinct estimates");
    }
}
