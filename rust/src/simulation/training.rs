//! Training layer: the §2.4 / §7 mixed-row phase driver.
//!
//! Owns the synchronized training jobs colocated with the inference
//! services: one `TrainJob` per `servers_per_job` chunk of the row's
//! training tail, each advancing on the shared event queue with one
//! event per waveform phase per *job* — every member server switches
//! phase at the same instant, so the row-level power swings coordinate
//! exactly as the paper observes. Frequency caps change training power
//! immediately (through `Sim::refresh_power`) but stretch timing only
//! from the next gradient-sync barrier on; the cost is reported as
//! iteration-time inflation ([`crate::metrics::TrainingMetrics`]).

use crate::cluster::hierarchy::JobKind;
use crate::obs::{EventKind, Observer};
use crate::power::gpu::CapMode;
use crate::power::training::{TrainingPowerModel, TrainingProfile};
use crate::sim::secs;

use super::core::{Ev, Sim};
use super::SimConfig;

/// Mixed-row parameters: colocate synchronized training jobs with the
/// inference services (§2.4 contrast, §7 mixing direction).
#[derive(Debug, Clone)]
pub struct MixedRowConfig {
    /// Fraction of the *deployed* servers running training (0.0 = pure
    /// inference, 1.0 = pure training row). The training servers are
    /// carved deterministically off the tail of the row so every
    /// fraction shares one inference workload realization (see
    /// [`crate::workload::spec::mark_training`]).
    pub training_fraction: f64,
    /// Servers per synchronized job; 0 means one job spans every
    /// training server (the paper's large-job worst case, maximally
    /// coordinated row swings).
    pub servers_per_job: usize,
    /// Offset between consecutive jobs' start times, seconds. Staggered
    /// jobs de-align their synchronization troughs, shrinking the
    /// row-level swing — the §7 lever an operator controls.
    pub job_stagger_s: f64,
    /// Iteration waveform every job runs.
    pub profile: TrainingProfile,
}

impl Default for MixedRowConfig {
    fn default() -> Self {
        MixedRowConfig {
            training_fraction: 0.0,
            servers_per_job: 0,
            job_stagger_s: 0.0,
            profile: TrainingProfile::large_llm(),
        }
    }
}

/// One synchronized training job: every member server switches waveform
/// phase on the same event, so row-level swings coordinate (§2.4).
pub(crate) struct TrainJob {
    /// Indices into the server layer's state vector.
    pub(crate) servers: Vec<usize>,
    pub(crate) model: TrainingPowerModel,
    /// Job start time (staggered per job).
    pub(crate) start_s: f64,
    /// Generation counter invalidating stale TrainPhase events.
    pub(crate) gen: u32,
    /// Current phase index into `TrainingProfile::phase_levels`.
    pub(crate) phase_idx: usize,
    pub(crate) iter_started_s: f64,
    /// Wall time of the in-flight iteration (stretched by the cap that
    /// was active when it started).
    pub(crate) iter_wall_s: f64,
}

/// The mixed-row training jobs (empty on inference-only rows).
pub(crate) struct TrainingLayer {
    pub(crate) jobs: Vec<TrainJob>,
}

impl TrainingLayer {
    /// One synchronized job per `servers_per_job` chunk of the training
    /// tail; 0 = a single row-spanning job (§2.4's large-job worst
    /// case). RNG-free: job structure derives only from the row's
    /// (already carved) training tail and the mixed config.
    pub(crate) fn new(cfg: &SimConfig, row: &crate::cluster::hierarchy::Row) -> TrainingLayer {
        let mut jobs = Vec::new();
        if let Some(m) = &cfg.mixed {
            let train_idxs: Vec<usize> = row
                .servers
                .iter()
                .enumerate()
                .filter(|(_, s)| s.job == JobKind::Training)
                .map(|(i, _)| i)
                .collect();
            if !train_idxs.is_empty() {
                let per =
                    if m.servers_per_job == 0 { train_idxs.len() } else { m.servers_per_job };
                for (j, chunk) in train_idxs.chunks(per.max(1)).enumerate() {
                    jobs.push(TrainJob {
                        servers: chunk.to_vec(),
                        model: TrainingPowerModel::with_calib(m.profile, row.power_model.calib),
                        start_s: j as f64 * m.job_stagger_s.max(0.0),
                        gen: 0,
                        phase_idx: 0,
                        iter_started_s: 0.0,
                        iter_wall_s: m.profile.iter_time_s,
                    });
                }
            }
        }
        TrainingLayer { jobs }
    }
}

impl<'a, O: Observer> Sim<'a, O> {
    /// Cap governing a job right now. Every member shares the LP class
    /// (training is priority-pinned) and the brake is row-wide, so one
    /// member is representative.
    pub(crate) fn train_cap(&self, j: usize) -> CapMode {
        self.cap_mode(self.training.jobs[j].servers[0])
    }

    /// Push the job's current waveform level to every member server —
    /// one event, all members: this is the cross-server iteration
    /// synchronization that makes row-level swings coordinate.
    pub(crate) fn apply_train_level(&mut self, j: usize) {
        let level =
            self.training.jobs[j].model.profile.phase_levels()[self.training.jobs[j].phase_idx];
        if O::ENABLED {
            let phase = self.training.jobs[j].phase_idx as u32;
            self.obs
                .event(self.core.now_s, EventKind::TrainPhase { job: j as u32, phase, level });
        }
        let members = std::mem::take(&mut self.training.jobs[j].servers);
        for &idx in &members {
            self.servers.train_level[idx] = level;
            self.refresh_power(idx);
        }
        self.training.jobs[j].servers = members;
    }

    pub(crate) fn schedule_train_phase(&mut self, j: usize) {
        let job = &self.training.jobs[j];
        let b = job.model.profile.phase_bounds();
        let end_s = job.iter_started_s + job.iter_wall_s * b[job.phase_idx + 1];
        let gen = job.gen;
        // Same +1 µs guard as request phases: integer-microsecond
        // rounding must never land before the true boundary.
        self.core.queue.schedule_at(secs(end_s) + 1, Ev::TrainPhase { job: j as u32, gen });
    }

    /// Begin an iteration. Timing is fixed by the cap active *now*:
    /// caps arriving mid-iteration change power immediately (via
    /// [`Sim::refresh_power`]) but stretch timing only from the next
    /// gradient-sync barrier on — barriers quantize the performance
    /// effect at iteration granularity.
    pub(crate) fn start_train_iteration(&mut self, j: usize, now_s: f64) {
        let cap = self.train_cap(j);
        let job = &mut self.training.jobs[j];
        job.gen = job.gen.wrapping_add(1);
        job.phase_idx = 0;
        job.iter_started_s = now_s;
        job.iter_wall_s = job.model.iter_time_s(cap);
        self.apply_train_level(j);
        self.schedule_train_phase(j);
    }

    pub(crate) fn on_train_phase(&mut self, j: usize, gen: u32, now_s: f64) {
        if self.training.jobs[j].gen != gen {
            return; // stale (the job has since restarted an iteration)
        }
        if self.training.jobs[j].phase_idx + 1 >= 4 {
            // Sync barrier reached: the iteration is complete.
            let wall = now_s - self.training.jobs[j].iter_started_s;
            self.acct.report.train.record(wall);
            if O::ENABLED {
                self.obs.event(now_s, EventKind::TrainIter { job: j as u32, wall_s: wall });
            }
            self.start_train_iteration(j, now_s);
        } else {
            self.training.jobs[j].phase_idx += 1;
            self.apply_train_level(j);
            self.schedule_train_phase(j);
        }
    }
}
