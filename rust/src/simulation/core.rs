//! Core layer: the discrete event loop over [`crate::sim::EventQueue`].
//!
//! Owns the event vocabulary (`Ev`), simulated time and the run
//! horizon, and the `Sim` composition itself: the simulator is
//! nothing but the five domain layers wired to one queue, with this
//! module's loop popping events and dispatching each to the layer that
//! owns it ([`super::servers`], [`super::control`],
//! [`super::training`], [`super::faults`]) while
//! [`super::accounting`] settles energy across every transition.
//!
//! Determinism contract: the queue orders ties by insertion sequence,
//! every random stream is forked once at construction in a fixed order
//! (see `ServerLayer::new` in [`super::servers`]), and `now_s` is set
//! from the popped event time before any handler runs — so a config +
//! seed pins the entire run bit-for-bit, which is what lets
//! [`crate::exec`] fan scenario batches out across threads without
//! changing a single reported number.

use crate::cluster::hierarchy::JobKind;
use crate::metrics::RunReport;
use crate::obs::{NoopObserver, Observer};
use crate::sim::{secs, to_secs, EventQueue, SimTime};

use super::accounting::Accounting;
use super::adapt::AdaptLayer;
use super::control::ControlLayer;
use super::faults::FaultLayer;
use super::servers::ServerLayer;
use super::training::TrainingLayer;
use super::SimConfig;

/// The simulator's event vocabulary. Every variant is owned by exactly
/// one layer; the loop in [`Sim::run`] is pure dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub(crate) enum Ev {
    /// A request arrives at a server.
    Arrival { server: u32 },
    /// The current phase of the server's in-flight request completes
    /// (valid only if `gen` matches the server's generation counter).
    PhaseEnd { server: u32, gen: u32 },
    /// PDU sample + policy tick.
    Telemetry,
    /// An OOB command becomes effective.
    OobApply,
    /// A training job begins its first iteration (staggered job starts).
    TrainStart { job: u32 },
    /// A training job's current waveform phase ends (valid only if `gen`
    /// matches the job's generation counter).
    TrainPhase { job: u32, gen: u32 },
    /// Record a point of the downsampled power series.
    SampleSeries,
    /// A scheduled fault episode begins (index into the run's fault plan).
    FaultStart { fault: u32 },
    /// A scheduled fault episode ends (degraded state is restored).
    FaultEnd { fault: u32 },
    /// Adaptive-controller window boundary: evaluate the window's
    /// feedback and maybe retune (scheduled only when
    /// [`SimConfig::adapt`](super::SimConfig) is set).
    RetuneCheck,
    End,
}

/// Event-loop state: the queue, the horizon, and simulation "now".
pub(crate) struct Core {
    pub(crate) queue: EventQueue<Ev>,
    pub(crate) horizon: SimTime,
    /// Simulation "now" (set by the event loop before each handler), so
    /// power changes can settle the energy accumulator.
    pub(crate) now_s: f64,
}

impl Core {
    pub(crate) fn new(cfg: &SimConfig) -> Core {
        // Size the queue from the config instead of a hard constant
        // (ISSUE 10): the steady-state population is ~2 pending events
        // per deployed server (next Arrival + in-flight PhaseEnd), plus
        // both edges of every fault episode (seeded up front), plus
        // fixed slack for the recurring singletons (Telemetry, series
        // sampling, OOB applies, training phases, retune checks, End).
        // Large rows thus never regrow the heap mid-run.
        let fault_events = cfg.faults.as_ref().map(|p| p.len()).unwrap_or(0);
        Core {
            queue: EventQueue::with_capacity(2 * cfg.deployed_servers + 2 * fault_events + 64),
            horizon: secs(cfg.weeks * 7.0 * 86_400.0),
            now_s: 0.0,
        }
    }
}

/// The row simulator: a composition of the extracted layers. Every
/// field is one layer with an explicit boundary; cross-layer effects go
/// through `Sim` methods defined in the layer that owns the state they
/// mutate.
///
/// The observer is a generic (not a trait object) so that with the
/// default [`NoopObserver`] — whose `ENABLED` is `false` — every
/// `if O::ENABLED` emission site monomorphizes away and the unobserved
/// run costs nothing and stays bit-identical.
pub(crate) struct Sim<'a, O: Observer> {
    pub(crate) cfg: &'a SimConfig,
    pub(crate) core: Core,
    pub(crate) servers: ServerLayer,
    pub(crate) control: ControlLayer,
    pub(crate) training: TrainingLayer,
    pub(crate) faults: FaultLayer,
    pub(crate) acct: Accounting,
    /// The adaptive outer loop; `None` (the default) keeps every one of
    /// its hooks off the hot path and the run bit-identical.
    pub(crate) adapt: Option<AdaptLayer>,
    pub(crate) obs: &'a mut O,
}

/// Run one simulation; returns the report (the [`super::run`] entry).
pub(crate) fn run_sim(cfg: &SimConfig) -> RunReport {
    let mut obs = NoopObserver;
    Sim::new(cfg, &mut obs).run()
}

/// Run one simulation with an observer attached (the
/// [`super::run_observed`] entry).
pub(crate) fn run_sim_observed<O: Observer>(cfg: &SimConfig, obs: &mut O) -> RunReport {
    Sim::new(cfg, obs).run()
}

impl<'a, O: Observer> Sim<'a, O> {
    /// Assemble the layers. Construction order is fixed: the server
    /// layer first (it owns every random stream), then the RNG-free
    /// layers in any order — kept explicit here so the bit-identity
    /// contract survives future edits.
    pub(crate) fn new(cfg: &'a SimConfig, obs: &'a mut O) -> Self {
        let servers = ServerLayer::new(cfg);
        let training = TrainingLayer::new(cfg, &servers.row);
        let mut control = ControlLayer::new(cfg);
        let faults = FaultLayer::new(cfg, servers.n_servers());
        let mut acct = Accounting::new();
        if !training.jobs.is_empty() {
            acct.report.train.nominal_iter_s =
                cfg.mixed.as_ref().map(|m| m.profile.iter_time_s).unwrap_or(0.0);
        }
        // The adaptive layer is RNG-free; when present it owns the
        // (T1, T2) knob from t = 0, so actuate its initial rung here.
        let adapt = cfg.adapt.as_ref().map(|a| AdaptLayer::new(a, cfg));
        if let Some(ad) = &adapt {
            let (t1, t2) = ad.ctl.thresholds();
            control.policy.cfg.t1 = t1;
            control.policy.cfg.t2 = t2;
        }
        Sim { cfg, core: Core::new(cfg), servers, control, training, faults, acct, adapt, obs }
    }

    // ---- main loop -------------------------------------------------------

    pub(crate) fn run(mut self) -> RunReport {
        // Initial power state.
        for idx in 0..self.servers.n_servers() {
            self.refresh_power(idx);
        }
        // Seed events. Training servers take no request arrivals: their
        // load is the iteration waveform, driven by TrainStart below.
        for idx in 0..self.servers.n_servers() {
            if self.servers.kind[idx] == JobKind::Training {
                continue;
            }
            let t = self.servers.cold[idx].arrivals.next_after(0.0);
            self.core.queue.schedule_at(secs(t), Ev::Arrival { server: idx as u32 });
        }
        for j in 0..self.training.jobs.len() {
            let start = self.training.jobs[j].start_s;
            self.core.queue.schedule_at(secs(start), Ev::TrainStart { job: j as u32 });
        }
        self.core.queue.schedule_at(0, Ev::Telemetry);
        if self.cfg.series_sample_s > 0.0 {
            self.core.queue.schedule_at(0, Ev::SampleSeries);
            // The series length is known from the horizon: one sample
            // per period plus the t=0 sample. Reserving up front keeps
            // the hot loop free of reallocation stalls (ISSUE 10).
            let samples = (to_secs(self.core.horizon) / self.cfg.series_sample_s) as usize + 2;
            self.acct.report.power_series.reserve(samples);
        }
        // Fault timeline: an empty plan schedules nothing, keeping the
        // run bit-identical to one with no plan at all.
        for i in 0..self.faults.events.len() {
            let f = self.faults.events[i];
            self.core.queue.schedule_at(secs(f.start_s), Ev::FaultStart { fault: i as u32 });
            self.core.queue.schedule_at(secs(f.end_s()), Ev::FaultEnd { fault: i as u32 });
        }
        // Adaptive outer loop: an absent config schedules nothing,
        // keeping the run bit-identical to one with no controller.
        if let Some(ad) = &self.adapt {
            self.core.queue.schedule_at(secs(ad.ctl.cfg.window_s), Ev::RetuneCheck);
        }
        let horizon = self.core.horizon;
        self.core.queue.schedule_at(horizon, Ev::End);

        while let Some((t, ev)) = self.core.queue.pop() {
            let now_s = to_secs(t);
            self.core.now_s = now_s;
            match ev {
                Ev::Arrival { server } => self.on_arrival(server as usize, now_s),
                Ev::PhaseEnd { server, gen } => self.on_phase_end(server as usize, gen, now_s),
                Ev::Telemetry => self.on_telemetry(now_s),
                Ev::OobApply => self.on_oob_apply(now_s),
                Ev::TrainStart { job } => self.start_train_iteration(job as usize, now_s),
                Ev::TrainPhase { job, gen } => self.on_train_phase(job as usize, gen, now_s),
                Ev::SampleSeries => {
                    let p = self.normalized_row_power();
                    self.acct.report.power_series.push((now_s, p));
                    self.core.queue.schedule_in(secs(self.cfg.series_sample_s), Ev::SampleSeries);
                }
                Ev::FaultStart { fault } => self.on_fault_start(fault as usize, now_s),
                Ev::FaultEnd { fault } => self.on_fault_end(fault as usize, now_s),
                Ev::RetuneCheck => self.on_retune_check(now_s),
                // The End sentinel dispatches nothing: the single
                // horizon check below is the loop's only exit.
                Ev::End => {}
            }
            // Single horizon exit (ISSUE 10 collapsed the redundant
            // `Ev::End => break` arm into this check). At-horizon
            // semantics, pinned by the golden tests: events scheduled
            // exactly AT the horizon during setup (before End, so ahead
            // of it in tie order) still dispatch once, then the run
            // ends on this check; events scheduled at the horizon
            // *during* the run land after End in tie order and never
            // dispatch. `report.events` counts the End pop either way.
            if t >= horizon {
                break;
            }
        }

        // Finalize. Close the last ground-truth accounting segment at
        // the horizon, then score the injected incidents.
        self.core.now_s = to_secs(horizon);
        self.settle_energy();
        self.finalize_incidents();
        if self.control.braked {
            self.acct.report.brake_time_s += to_secs(horizon) - self.control.brake_engaged_at;
        }
        self.acct.report.brake_events = self.control.policy.brake_events;
        self.acct.report.duration_s = to_secs(horizon);
        self.acct.report.events = self.core.queue.popped();
        if O::ENABLED {
            self.obs.counter("events-dispatched", self.core.queue.popped());
            self.obs.counter("queue-scheduled", self.core.queue.scheduled());
        }
        let (peak, p99, mean) = self.control.telemetry.utilization();
        self.acct.report.power_peak = peak;
        self.acct.report.power_p99 = p99;
        self.acct.report.power_mean = mean;
        let spikes = self.control.telemetry.spike_stats(&[2.0, 5.0, 40.0]);
        self.acct.report.spike_2s = spikes[0].max_rise;
        self.acct.report.spike_5s = spikes[1].max_rise;
        self.acct.report.spike_40s = spikes[2].max_rise;
        // Adaptive controller summary: close the time-weighted level
        // integral at the horizon so `mean_added` covers the whole run.
        if let Some(mut ad) = self.adapt.take() {
            let horizon_s = to_secs(horizon);
            ad.level_time_acc += (horizon_s - ad.last_level_change_s).max(0.0) * ad.last_level;
            ad.report.mean_added =
                if horizon_s > 0.0 { ad.level_time_acc / horizon_s } else { 0.0 };
            ad.report.final_added = ad.ctl.level();
            let (t1, t2) = ad.ctl.thresholds();
            ad.report.final_t1 = t1;
            ad.report.final_t2 = t2;
            self.acct.report.adapt = Some(ad.report);
        }
        self.acct.report
    }
}
