//! Accounting layer: the energy accumulator and run bookkeeping.
//!
//! Owns the [`RunReport`] under construction and the exact energy
//! integral behind it. Two distinct views of row power are settled
//! here, deliberately kept apart:
//!
//! * **What the meter reports** (`Sim::averaged_row_power`): real PDU
//!   meters report power averaged over the sampling period, not
//!   instantaneous draw — sub-second prompt-spike alignments are
//!   smoothed by the meter (and are harmless physically: the UPS
//!   tolerates 133% load for 10 s, §4.E). Table 2's spike statistics
//!   are computed on these averaged readings, and a meter-bias fault
//!   corrupts exactly this view.
//! * **Ground truth** (`Sim::settle_energy`): power is constant over
//!   each settled segment, so the budget-violation accounting
//!   ([`crate::metrics::ResilienceMetrics`]) is exact, not sampled —
//!   and independent of what the possibly-lying meter says.

use crate::metrics::RunReport;
use crate::obs::{EventKind, Observer};

use super::core::Sim;

/// Energy accumulator, settlement clocks, and the report being built.
pub(crate) struct Accounting {
    /// Energy accumulator for window-averaged PDU readings, watt-seconds.
    pub(crate) energy_acc_ws: f64,
    pub(crate) last_power_change_s: f64,
    pub(crate) last_telemetry_s: f64,
    /// Whether the last settled segment was over the effective budget.
    /// Observability bookkeeping only (violation-start/contained edge
    /// detection); maintained only when an observer is attached, never
    /// read by the simulation itself.
    pub(crate) in_violation: bool,
    pub(crate) report: RunReport,
}

impl Accounting {
    pub(crate) fn new() -> Accounting {
        Accounting {
            energy_acc_ws: 0.0,
            last_power_change_s: 0.0,
            last_telemetry_s: 0.0,
            in_violation: false,
            report: RunReport::default(),
        }
    }
}

impl<'a, O: Observer> Sim<'a, O> {
    /// Settle the energy accumulator up to the current event time (must
    /// run before any change to the row power or to the effective
    /// budget). Power is constant over the settled segment, so the
    /// ground-truth violation accounting here is exact, not sampled —
    /// and independent of what the (possibly miscalibrated) meter says.
    pub(crate) fn settle_energy(&mut self) {
        if O::ENABLED {
            self.obs.settle();
        }
        let dt = (self.core.now_s - self.acct.last_power_change_s).max(0.0);
        if dt > 0.0 {
            self.acct.energy_acc_ws += self.servers.row_power_w * dt;
            let scaled_w = self.cfg.power_scale * self.servers.row_power_w;
            let budget_eff_w = self.servers.row.budget_w * self.faults.budget_mult;
            if O::ENABLED {
                // Violation edge detection: the settled segment had
                // constant power, so the crossing happened when the
                // segment began. Bookkeeping is observer-only — the
                // simulation itself never reads `in_violation`.
                let seg_start = self.acct.last_power_change_s;
                if scaled_w > budget_eff_w && !self.acct.in_violation {
                    self.acct.in_violation = true;
                    self.obs.event(
                        seg_start,
                        EventKind::ViolationStart { over_w: scaled_w - budget_eff_w },
                    );
                } else if scaled_w <= budget_eff_w && self.acct.in_violation {
                    self.acct.in_violation = false;
                    self.obs.event(seg_start, EventKind::ViolationContained);
                }
            }
            let r = &mut self.acct.report.resilience;
            r.true_peak_norm = r.true_peak_norm.max(scaled_w / budget_eff_w);
            if scaled_w > budget_eff_w {
                r.violation_s += dt;
                r.overshoot_ws += (scaled_w - budget_eff_w) * dt;
                r.peak_overshoot_w = r.peak_overshoot_w.max(scaled_w - budget_eff_w);
                if let Some(i) = self.faults.cur_incident {
                    self.faults.incident_last_violation[i] = Some(self.core.now_s);
                }
            } else if let Some(i) = self.faults.cur_incident {
                // The row is back under budget: once the incident's
                // episode is over, stop attributing to it — later
                // violations (e.g. natural diurnal excursions hours
                // after the fault) are not this incident's tail. A
                // violation straddling the episode end keeps
                // attributing until it is actually contained.
                if self.core.now_s >= self.faults.events[i].end_s() {
                    self.faults.cur_incident = None;
                }
            }
        }
        self.acct.last_power_change_s = self.core.now_s;
    }

    /// Window-averaged normalized power since the last telemetry sample —
    /// what the PDU meter actually *reports*: scaled by any active meter
    /// miscalibration and normalized against the effective budget (a
    /// feed loss raises the manager-visible fraction because the manager
    /// knows the budget shrank).
    pub(crate) fn averaged_row_power(&mut self) -> f64 {
        self.settle_energy();
        let window = (self.core.now_s - self.acct.last_telemetry_s).max(1e-9);
        let avg_w = self.acct.energy_acc_ws / window;
        self.acct.energy_acc_ws = 0.0;
        self.acct.last_telemetry_s = self.core.now_s;
        self.faults.meter_bias * self.cfg.power_scale * avg_w
            / (self.servers.row.budget_w * self.faults.budget_mult)
    }

    /// Instantaneous normalized row power (the power-series sample).
    pub(crate) fn normalized_row_power(&self) -> f64 {
        self.cfg.power_scale * self.servers.row_power_w / self.servers.row.budget_w
    }
}
