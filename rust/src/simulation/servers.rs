//! Server layer: row provisioning and per-server power settlement.
//!
//! Owns everything physical about the row — the calibrated model spec,
//! the Table-4 workload assignment, the provisioned [`Row`], and the
//! live per-server state (in-flight request, buffered request, arrival
//! process, frequency cap, cached power draw). The request lifecycle
//! handlers (`Sim::on_arrival` / `Sim::on_phase_end`) and the
//! work-conserving cap application (`Sim::set_server_cap`) live here
//! because their effects are entirely server-local; row-wide actuation
//! (the powerbrake) lives in [`super::control`].
//!
//! # State layout (ISSUE 10)
//!
//! Per-server state is struct-of-arrays: the fields the row-wide sweeps
//! touch (`power_w`, `freq_cap_mhz`, `gen`, `last_advance_s`,
//! `train_level` — hit by brake actuation in [`super::control`], cap
//! fan-out, initial provisioning, and every `refresh_power`) are
//! parallel `Vec`s indexed by server, so a sweep over the row walks
//! each hot field cache-linearly instead of striding over ~200-byte
//! `ServerState` records to pick one field out of each. The immutable
//! attributes (`priority`, `kind`) are parallel vectors too (the cap
//! fan-out filters on priority row-wide), and everything touched only
//! by one server's own lifecycle events — in-flight request, buffer,
//! arrival process, RNG — stays together in the cold `ServerCold`
//! array. `docs/PERFORMANCE.md` has the layout rationale and numbers.
//!
//! Power settlement contract: any change to a server's draw goes
//! through `Sim::refresh_power`, which first settles the energy
//! accumulator ([`super::accounting`]) so the ground-truth violation
//! integral sees a piecewise-constant power signal with exact segment
//! boundaries. `refresh_power` evaluates the server model through the
//! exact-input memo (the private `super::powermemo` module) —
//! bit-identical to direct evaluation, a fraction of the cost.

use crate::characterize::catalog::{self, ModelSpec};
use crate::cluster::hierarchy::{JobKind, Priority, Row};
use crate::obs::Observer;
use crate::perfmodel::{ExecPhase, RequestExec};
use crate::power::gpu::{CapMode, Phase};
use crate::sim::secs;
use crate::util::rng::Rng;
use crate::workload::arrivals::ArrivalProcess;
use crate::workload::spec::{assign_servers, sample_request, WorkloadSpec};

use super::core::{Ev, Sim};
use super::powermemo::PowerMemo;
use super::SimConfig;

#[derive(Debug, Clone)]
pub(crate) struct InFlight {
    pub(crate) exec: RequestExec,
    pub(crate) arrived_s: f64,
    pub(crate) priority: Priority,
}

#[derive(Debug, Clone)]
pub(crate) struct QueuedReq {
    pub(crate) input: f64,
    pub(crate) output: f64,
    pub(crate) arrived_s: f64,
}

/// Cold per-server state: touched only by the owning server's own
/// lifecycle events (arrival, phase end), never by row-wide sweeps.
pub(crate) struct ServerCold {
    pub(crate) workload_idx: usize,
    pub(crate) current: Option<InFlight>,
    pub(crate) queued: Option<QueuedReq>,
    pub(crate) arrivals: ArrivalProcess,
    pub(crate) rng: Rng,
}

/// The provisioned row plus live per-server state (struct-of-arrays —
/// see the module docs) and the incremental row power aggregate.
pub(crate) struct ServerLayer {
    pub(crate) model: ModelSpec,
    pub(crate) specs: Vec<WorkloadSpec>,
    pub(crate) row: Row,
    // -- hot per-server fields, parallel vectors indexed by server ----
    /// Current power draw in watts (cached for incremental row sum).
    pub(crate) power_w: Vec<f64>,
    pub(crate) freq_cap_mhz: Vec<Option<f64>>,
    /// Generation counter invalidating stale PhaseEnd events.
    pub(crate) gen: Vec<u32>,
    /// Time work was last advanced (for mid-flight cap changes).
    pub(crate) last_advance_s: Vec<f64>,
    /// Training servers only: the nominal GPU power fraction of the
    /// job's current waveform phase (idle before the job starts).
    pub(crate) train_level: Vec<f64>,
    // -- immutable per-server attributes ------------------------------
    pub(crate) priority: Vec<Priority>,
    pub(crate) kind: Vec<JobKind>,
    // -- cold per-server state ----------------------------------------
    pub(crate) cold: Vec<ServerCold>,
    pub(crate) row_power_w: f64,
    /// Exact-input power-evaluation memo (per run; see
    /// [`super::powermemo`]).
    pub(crate) memo: PowerMemo,
}

impl ServerLayer {
    /// Deployed server count (every parallel vector has this length).
    #[inline]
    pub(crate) fn n_servers(&self) -> usize {
        self.cold.len()
    }

    /// Provision the row: apply the robustness/SKU knobs to the catalog
    /// model, assign Table-4 workloads, carve the training tail, and
    /// derive per-server arrival rates from the target utilization.
    ///
    /// RNG contract: every random stream is forked here, in a fixed
    /// order, from a root seeded by `cfg.exp.seed` — the layer split
    /// must never reorder these forks (bit-identity depends on it).
    pub(crate) fn new(cfg: &SimConfig) -> ServerLayer {
        let mut model = catalog::find(&cfg.model_name).expect("model not in catalog");
        // Fig 17 robustness knob: workloads draw more than profiled.
        if cfg.workload_power_mult != 1.0 {
            model.power.prompt_peak_at_256 *= cfg.workload_power_mult;
            model.power.prompt_peak_at_8192 *= cfg.workload_power_mult;
            model.power.token_mean_at_b1 *= cfg.workload_power_mult;
            model.power.token_mean_at_b16 *= cfg.workload_power_mult;
        }
        // Fleet SKU knob: faster silicon shifts the latency anchors.
        if cfg.perf_mult != 1.0 {
            model.prompt_tokens_per_s *= cfg.perf_mult;
            model.decode_tokens_per_s *= cfg.perf_mult;
        }
        let mut power_model = cfg.server_model.clone().unwrap_or_else(|| {
            crate::power::server::ServerPowerModel { calib: model.power, ..Default::default() }
        });
        // An explicit server model carries its own calibration, so the
        // Fig-17 robustness multiplier must be applied to it directly
        // (the scaling above only touched the catalog-derived default).
        if cfg.server_model.is_some() && cfg.workload_power_mult != 1.0 {
            let c = &mut power_model.calib;
            c.prompt_peak_at_256 *= cfg.workload_power_mult;
            c.prompt_peak_at_8192 *= cfg.workload_power_mult;
            c.token_mean_at_b1 *= cfg.workload_power_mult;
            c.token_mean_at_b16 *= cfg.workload_power_mult;
        }
        let mut root_rng = Rng::new(cfg.exp.seed ^ 0x9E3779B97F4A7C15);
        let mut row = Row::provision(cfg.exp.row.num_servers, cfg.deployed_servers, power_model);
        let specs = crate::workload::spec::table4();
        assign_servers(&mut row, &specs, 0, cfg.lp_fraction_override, &mut root_rng);
        // Mixed rows: carve training servers off the tail AFTER the
        // inference assignment, so every training fraction consumes the
        // identical random stream (0% is bit-identical to `mixed: None`,
        // and sweeps interpolate on one fixed workload realization).
        let train_count = cfg
            .mixed
            .as_ref()
            .map(|m| {
                ((m.training_fraction * row.servers.len() as f64).round() as usize)
                    .min(row.servers.len())
            })
            .unwrap_or(0);
        if train_count > 0 {
            crate::workload::spec::mark_training(&mut row, train_count);
        }

        // Per-workload peak arrival rate from the target utilization:
        // rate = utilization / E[nominal service time of that workload].
        // The Monte Carlo estimate is memoized in `super::calib` (ISSUE
        // 10); `fork_seed` consumes the root stream exactly as `fork`
        // did, so the memo changes no trace bits.
        let est_seed = root_rng.fork_seed(77);
        let mean_service = super::calib::mean_service_for(
            est_seed,
            &cfg.model_name,
            cfg.perf_mult,
            cfg.workload_power_mult,
            &model,
            &specs,
        );

        let n = row.servers.len();
        let idle_frac = row.power_model.calib.idle_frac;
        let mut priority = Vec::with_capacity(n);
        let mut kind = Vec::with_capacity(n);
        let mut cold = Vec::with_capacity(n);
        for s in &row.servers {
            let rate = cfg.peak_utilization / mean_service[s.workload_idx];
            priority.push(s.priority);
            kind.push(s.job);
            cold.push(ServerCold {
                workload_idx: s.workload_idx,
                current: None,
                queued: None,
                arrivals: ArrivalProcess::new(rate, root_rng.fork(1000 + s.id as u64))
                    .with_phase(cfg.diurnal_phase_s)
                    .with_drift(cfg.drift.clone(), cfg.weeks),
                rng: root_rng.fork(2000 + s.id as u64),
            });
        }

        ServerLayer {
            model,
            specs,
            row,
            power_w: vec![0.0; n],
            freq_cap_mhz: vec![None; n],
            gen: vec![0; n],
            last_advance_s: vec![0.0; n],
            train_level: vec![idle_frac; n],
            priority,
            kind,
            cold,
            row_power_w: 0.0,
            memo: PowerMemo::new(),
        }
    }
}

impl<'a, O: Observer> Sim<'a, O> {
    // ---- power bookkeeping ------------------------------------------------

    pub(crate) fn freq_ratio(&self, idx: usize) -> f64 {
        if self.control.braked {
            return self.cfg.exp.policy.brake_freq_mhz / self.cfg.exp.policy.max_freq_mhz;
        }
        match self.servers.freq_cap_mhz[idx] {
            Some(mhz) => mhz / self.cfg.exp.policy.max_freq_mhz,
            None => 1.0,
        }
    }

    pub(crate) fn cap_mode(&self, idx: usize) -> CapMode {
        if self.control.braked {
            CapMode::FreqCap { mhz: self.cfg.exp.policy.brake_freq_mhz }
        } else {
            match self.servers.freq_cap_mhz[idx] {
                Some(mhz) => CapMode::FreqCap { mhz },
                None => CapMode::None,
            }
        }
    }

    pub(crate) fn server_phase(&self, idx: usize) -> Phase {
        match &self.servers.cold[idx].current {
            None => Phase::Idle,
            Some(inf) => match inf.exec.phase() {
                ExecPhase::Prompt => Phase::Prompt { total_input: inf.exec.input * inf.exec.batch },
                ExecPhase::Token | ExecPhase::Done => Phase::Token { batch: inf.exec.batch },
            },
        }
    }

    /// Recompute one server's power and update the row aggregate. The
    /// model evaluation goes through the exact-input memo — identical
    /// bits to a direct `server_power_w` call at a fraction of the cost.
    pub(crate) fn refresh_power(&mut self, idx: usize) {
        self.settle_energy();
        let w = match self.servers.kind[idx] {
            JobKind::Inference => {
                let phase = self.server_phase(idx);
                let cap = self.cap_mode(idx);
                self.servers.memo.inference_w(&self.servers.row.power_model, phase, cap)
            }
            // Training power is absolute (the §2.4 waveform drives the
            // GPUs directly); `power_scale` is an inference-serving
            // calibration, so divide it out here — the row aggregate
            // multiplies it back in `normalized_row_power`.
            JobKind::Training => {
                let cap = self.cap_mode(idx);
                let nominal = self.servers.train_level[idx];
                self.servers.memo.training_w(&self.servers.row.power_model, nominal, cap)
                    / self.cfg.power_scale
            }
        };
        self.servers.row_power_w += w - self.servers.power_w[idx];
        self.servers.power_w[idx] = w;
    }

    // ---- request lifecycle --------------------------------------------

    pub(crate) fn start_request(
        &mut self,
        idx: usize,
        input: f64,
        output: f64,
        arrived_s: f64,
        now_s: f64,
    ) {
        let exec = RequestExec::new(&self.servers.model, input, output, 1.0);
        self.servers.cold[idx].current = Some(InFlight {
            exec,
            arrived_s,
            priority: self.servers.priority[idx],
        });
        self.servers.last_advance_s[idx] = now_s;
        self.servers.gen[idx] = self.servers.gen[idx].wrapping_add(1);
        self.refresh_power(idx);
        self.schedule_phase_end(idx, now_s);
    }

    pub(crate) fn schedule_phase_end(&mut self, idx: usize, now_s: f64) {
        let ratio = self.freq_ratio(idx);
        let wall = match &self.servers.cold[idx].current {
            Some(inf) if inf.exec.phase() != ExecPhase::Done => {
                inf.exec.wall_to_phase_end(&self.servers.model, ratio)
            }
            _ => return,
        };
        let gen = self.servers.gen[idx];
        // +1 µs guard: `secs` rounds to integer microseconds, which can
        // land *before* the true phase end and loop the event at the same
        // timestamp. Overshooting by a microsecond guarantees progress.
        self.core
            .queue
            .schedule_at(secs(now_s + wall) + 1, Ev::PhaseEnd { server: idx as u32, gen });
    }

    /// Advance the in-flight request's work to `now` at the *current*
    /// ratio (call BEFORE changing the ratio).
    pub(crate) fn advance_work(&mut self, idx: usize, now_s: f64) {
        let ratio = self.freq_ratio(idx);
        let last = self.servers.last_advance_s[idx];
        if let Some(inf) = &mut self.servers.cold[idx].current {
            let dt = (now_s - last).max(0.0);
            if dt > 0.0 {
                inf.exec.advance(&self.servers.model, ratio, dt);
            }
        }
        self.servers.last_advance_s[idx] = now_s;
    }

    /// Apply a frequency change to one server (work-conserving).
    pub(crate) fn set_server_cap(&mut self, idx: usize, cap: Option<f64>, now_s: f64) {
        if self.servers.freq_cap_mhz[idx] == cap {
            return;
        }
        self.advance_work(idx, now_s);
        self.servers.freq_cap_mhz[idx] = cap;
        self.servers.gen[idx] = self.servers.gen[idx].wrapping_add(1);
        self.refresh_power(idx);
        self.schedule_phase_end(idx, now_s);
    }

    // ---- event handlers -------------------------------------------------

    pub(crate) fn on_arrival(&mut self, idx: usize, now_s: f64) {
        // Schedule the next arrival for this server.
        let next = self.servers.cold[idx].arrivals.next_after(now_s);
        self.core.queue.schedule_at(secs(next), Ev::Arrival { server: idx as u32 });

        let spec = &self.servers.specs[self.servers.cold[idx].workload_idx];
        let (input, output) = sample_request(spec, &mut self.servers.cold[idx].rng);
        // Adaptive actuation: servers beyond the controller's active
        // prefix are racked but not taking traffic. The next arrival is
        // still scheduled and the request still sampled (above), so
        // every random stream advances identically at every level —
        // only then is the request shed to the rest of the fleet.
        if let Some(ad) = self.adapt.as_mut() {
            if idx >= ad.active_servers {
                ad.report.requests_shed += 1;
                return;
            }
        }
        if self.servers.cold[idx].current.is_none() {
            self.start_request(idx, input, output, now_s, now_s);
        } else if self.servers.cold[idx].queued.is_none() {
            self.servers.cold[idx].queued = Some(QueuedReq { input, output, arrived_s: now_s });
        } else {
            // Buffer full: request is rejected (load-balancer would retry
            // elsewhere; within this row it counts against throughput).
            let pri = self.servers.priority[idx];
            self.acct.report.by_priority(pri).dropped += 1;
        }
    }

    pub(crate) fn on_phase_end(&mut self, idx: usize, gen: u32, now_s: f64) {
        if self.servers.gen[idx] != gen {
            return; // stale (frequency changed; a new event is scheduled)
        }
        self.advance_work(idx, now_s);
        let phase = self.servers.cold[idx].current.as_ref().map(|i| i.exec.phase());
        match phase {
            Some(ExecPhase::Token) => {
                // Prompt just finished; token phase begins.
                self.servers.gen[idx] = self.servers.gen[idx].wrapping_add(1);
                self.refresh_power(idx);
                self.schedule_phase_end(idx, now_s);
            }
            Some(ExecPhase::Done) => {
                let inf = self.servers.cold[idx].current.take().unwrap();
                let actual = now_s - inf.arrived_s;
                self.acct.report.by_priority(inf.priority).record(
                    actual,
                    inf.exec.nominal_latency,
                    inf.exec.output,
                );
                if let Some(ad) = self.adapt.as_mut() {
                    if inf.priority == Priority::High {
                        ad.win_hp_actual += actual;
                        ad.win_hp_nominal += inf.exec.nominal_latency;
                    }
                }
                self.servers.gen[idx] = self.servers.gen[idx].wrapping_add(1);
                // Pull the buffered request, if any.
                if let Some(q) = self.servers.cold[idx].queued.take() {
                    self.start_request(idx, q.input, q.output, q.arrived_s, now_s);
                } else {
                    self.refresh_power(idx);
                }
            }
            Some(ExecPhase::Prompt) | None => {
                // Numerical residue: reschedule to finish the phase.
                self.refresh_power(idx);
                self.schedule_phase_end(idx, now_s);
            }
        }
    }
}
