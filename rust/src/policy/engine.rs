//! The POLCA policy engine — Algorithm 1 — and the §6.3 baselines.
//!
//! The engine is a small deterministic state machine driven by the
//! (delayed) normalized row-power reading at every telemetry tick. It
//! emits [`Action`]s; the simulator (or a real rack manager) translates
//! them into OOB commands with their latencies. The engine is
//! deliberately decoupled from transport so the same logic drives the
//! discrete-event evaluation *and* the live serving coordinator.
//!
//! Per Algorithm 1:
//! ```text
//! P ← NormalizedRowPowerReading
//! if P > 1.0:        powerbrake (BMC, fast path); t1cap ← t2cap ← true
//! elif P > T2:       first time: LP → 1110 MHz; still above: HP → 1305 MHz
//! elif P > T1:       LP → 1275 MHz (A100 base clock)
//! if t2cap and P < T2 − buf:  uncap HP; LP caps relax to 1275 MHz
//! if t1cap and P < T1 − buf:  uncap LP
//! ```
//! The 5%-below-threshold uncap buffers implement the hysteresis that
//! prevents cap/uncap oscillation (§5.1 "Uncapping").
//!
//! Mixed rows (§7): the engine addresses servers by
//! [`crate::cluster::hierarchy::Priority`] class only, and training
//! jobs are *pinned* to the low-priority class
//! ([`crate::cluster::hierarchy::JobKind::fixed_priority`]) — so every
//! T1 crossing throttles the row's training ballast first, by
//! construction, and capping it costs iteration time instead of an
//! interactive SLO. No training-specific action is needed here; the
//! priority pinning is the §7 policy.

use crate::config::PolicyConfig;

/// Which policy drives the row (paper Fig 17/18 comparison set).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// POLCA dual-threshold (Algorithm 1).
    Polca,
    /// Single threshold at T2; caps only low-priority (to the T2 level).
    OneThreshLowPri,
    /// Single threshold at T2; caps everything aggressively.
    OneThreshAll,
    /// No proactive capping; powerbrake backstop only.
    NoCap,
}

impl PolicyKind {
    /// Display name (matches the paper's figure legends).
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Polca => "POLCA",
            PolicyKind::OneThreshLowPri => "1-Thresh-Low-Pri",
            PolicyKind::OneThreshAll => "1-Thresh-All",
            PolicyKind::NoCap => "No-cap",
        }
    }

    /// The full comparison set, in paper order.
    pub fn all() -> [PolicyKind; 4] {
        [PolicyKind::Polca, PolicyKind::OneThreshLowPri, PolicyKind::OneThreshAll, PolicyKind::NoCap]
    }

    /// Stable machine-readable slug, shared by the CLI (`--policy`) and
    /// the scenario TOML (`[policy] kind = "..."`).
    pub fn slug(&self) -> &'static str {
        match self {
            PolicyKind::Polca => "polca",
            PolicyKind::OneThreshLowPri => "1t-lp",
            PolicyKind::OneThreshAll => "1t-all",
            PolicyKind::NoCap => "nocap",
        }
    }

    /// The inverse of [`PolicyKind::slug`].
    pub fn from_slug(s: &str) -> Option<PolicyKind> {
        PolicyKind::all().into_iter().find(|k| k.slug() == s)
    }
}

/// Abstract control action emitted by the engine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Action {
    /// Cap all low-priority servers to the given SM clock.
    CapLp { mhz: f64 },
    /// Cap all high-priority servers to the given SM clock.
    CapHp { mhz: f64 },
    /// Remove the low-priority frequency cap.
    UncapLp,
    /// Remove the high-priority frequency cap.
    UncapHp,
    /// Engage the hardware powerbrake (row-wide, fast path).
    Brake,
    /// Release the powerbrake.
    ReleaseBrake,
}

/// Cap state the engine believes it has requested (its *intent*; the
/// fleet converges to it after the OOB latency).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct IntentState {
    /// Requested low-priority cap (None = uncapped).
    pub lp_cap_mhz: Option<f64>,
    /// Requested high-priority cap (None = uncapped).
    pub hp_cap_mhz: Option<f64>,
    /// Whether the powerbrake is requested.
    pub brake: bool,
}

/// The policy state machine.
///
/// ```
/// use polca::config::PolicyConfig;
/// use polca::policy::engine::{Action, PolicyEngine, PolicyKind};
///
/// let mut engine = PolicyEngine::new(PolicyKind::Polca, PolicyConfig::default());
/// // Nothing happens below T1 (0.80)...
/// assert!(engine.tick(0.0, 0.70).is_empty());
/// // ...and a reading above T2 (0.89) caps low-priority servers first.
/// let actions = engine.tick(60.0, 0.92);
/// assert_eq!(actions, vec![Action::CapLp { mhz: 1110.0 }]);
/// assert_eq!(engine.intent().lp_cap_mhz, Some(1110.0));
/// ```
#[derive(Debug, Clone)]
pub struct PolicyEngine {
    /// Which policy variant this engine runs.
    pub kind: PolicyKind,
    /// Threshold/setpoint configuration (Table 3).
    pub cfg: PolicyConfig,
    /// How long to wait after issuing the LP T2 cap before escalating to
    /// HP capping — the LP cap needs the OOB apply latency (~40 s) to
    /// show up in the power reading (Algorithm 1's "cap HP subsequently
    /// *if needed*").
    pub escalation_delay_s: f64,
    /// Containment escalation (fault mode, `None` = paper behavior): if
    /// the reading is still above T2 this long after the *full* cap set
    /// was engaged, the caps are visibly not biting — cap-ignoring
    /// servers, lost commands, or a lying meter — and the engine falls
    /// through to the fast brake path instead of waiting for the
    /// breaker at 100%.
    pub escalate_to_brake_after_s: Option<f64>,
    t1cap: bool,
    t2cap: bool,
    /// Within T2: whether the escalation to HP capping has fired.
    hp_capped: bool,
    /// When the T2 LP cap was issued (escalation clock).
    t2_issued_at: f64,
    /// Containment-escalation clock: first tick at which the reading
    /// was observed above T2 with the full cap set engaged (HP caps
    /// for POLCA, the T2 cap for the baselines). Reset whenever the
    /// reading dips back under T2, caps release, or the brake engages —
    /// every fresh excursion gets the full escalation window.
    stuck_above_t2_since: Option<f64>,
    brake: bool,
    /// Count of brake engagements (the Fig 18 metric).
    pub brake_events: u64,
    intent: IntentState,
}

impl PolicyEngine {
    /// A fresh engine with no caps engaged.
    pub fn new(kind: PolicyKind, cfg: PolicyConfig) -> Self {
        PolicyEngine {
            kind,
            cfg,
            escalation_delay_s: 45.0,
            escalate_to_brake_after_s: None,
            t1cap: false,
            t2cap: false,
            hp_capped: false,
            t2_issued_at: 0.0,
            stuck_above_t2_since: None,
            brake: false,
            brake_events: 0,
            intent: IntentState::default(),
        }
    }

    /// The cap state the engine currently intends the fleet to hold.
    pub fn intent(&self) -> IntentState {
        self.intent
    }

    /// Whether the engine believes the powerbrake is engaged.
    pub fn is_braked(&self) -> bool {
        self.brake
    }

    /// One telemetry tick at time `now_s`: consume the (delayed)
    /// normalized row power, emit the actions that change the fleet's
    /// cap state.
    pub fn tick(&mut self, now_s: f64, p: f64) -> Vec<Action> {
        match self.kind {
            PolicyKind::Polca => self.tick_polca(now_s, p),
            PolicyKind::OneThreshLowPri => self.tick_single(now_s, p, /*cap_hp=*/ false),
            PolicyKind::OneThreshAll => self.tick_single(now_s, p, /*cap_hp=*/ true),
            PolicyKind::NoCap => self.tick_nocap(p),
        }
    }

    // -- shared brake handling ------------------------------------------
    fn brake_check(&mut self, p: f64, out: &mut Vec<Action>) -> bool {
        if p > 1.0 {
            if !self.brake {
                self.brake = true;
                self.brake_events += 1;
                self.intent.brake = true;
                out.push(Action::Brake);
            }
            return true;
        }
        false
    }

    fn maybe_release_brake(&mut self, p: f64, release_below: f64, out: &mut Vec<Action>) {
        if self.brake && p < release_below {
            self.brake = false;
            self.intent.brake = false;
            out.push(Action::ReleaseBrake);
        }
    }

    /// Containment escalation (see [`PolicyEngine::escalate_to_brake_after_s`]):
    /// the reading has now been continuously above T2 for the whole
    /// escalation window despite the full cap set being engaged — the
    /// caps are visibly not biting, fall through to the fast brake path.
    fn maybe_escalate_to_brake(
        &mut self,
        now_s: f64,
        p: f64,
        full_caps: bool,
        out: &mut Vec<Action>,
    ) {
        let Some(after) = self.escalate_to_brake_after_s else {
            return;
        };
        if self.brake || !full_caps || p <= self.cfg.t2 {
            // Not a stuck excursion (or already braked): restart the
            // clock so the next crossing gets the full window.
            self.stuck_above_t2_since = None;
            return;
        }
        let since = *self.stuck_above_t2_since.get_or_insert(now_s);
        if now_s - since >= after {
            self.brake = true;
            self.brake_events += 1;
            self.intent.brake = true;
            out.push(Action::Brake);
        }
    }

    // -- POLCA Algorithm 1 ----------------------------------------------
    fn tick_polca(&mut self, now_s: f64, p: f64) -> Vec<Action> {
        let c = self.cfg.clone();
        let mut out = Vec::new();
        if self.brake_check(p, &mut out) {
            // Brake implies both cap levels engaged (Algorithm 1).
            self.t1cap = true;
            self.t2cap = true;
            self.hp_capped = true;
            self.set_lp(Some(c.lp_freq_t2_mhz), &mut out);
            self.set_hp(Some(c.hp_freq_t2_mhz), &mut out);
            return out;
        }
        // Release the brake once power is safely under T2.
        self.maybe_release_brake(p, c.t2 - c.t2_buffer, &mut out);

        if p > c.t2 {
            if !self.t2cap {
                self.t2cap = true;
                self.t1cap = true;
                self.t2_issued_at = now_s;
                // Start by capping only LP for T2.
                self.set_lp(Some(c.lp_freq_t2_mhz), &mut out);
            } else if !self.hp_capped && now_s - self.t2_issued_at >= self.escalation_delay_s {
                // The LP cap has had time to take effect (OOB latency)
                // and power is still above T2: cap HP subsequently.
                self.hp_capped = true;
                self.set_hp(Some(c.hp_freq_t2_mhz), &mut out);
            }
        } else if p > c.t1 {
            if !self.t1cap {
                self.t1cap = true;
                self.set_lp(Some(c.lp_freq_t1_mhz), &mut out);
            }
        }
        // Hysteresis-protected uncapping.
        if self.t2cap && p < c.t2 - c.t2_buffer {
            self.t2cap = false;
            self.hp_capped = false;
            self.set_hp(None, &mut out);
            // LP relaxes to the T1 level (still capped until below T1-buf).
            self.set_lp(Some(c.lp_freq_t1_mhz), &mut out);
        }
        if self.t1cap && !self.t2cap && p < c.t1 - c.t1_buffer {
            self.t1cap = false;
            self.set_lp(None, &mut out);
        }
        self.maybe_escalate_to_brake(now_s, p, self.hp_capped, &mut out);
        out
    }

    // -- single-threshold baselines --------------------------------------
    fn tick_single(&mut self, now_s: f64, p: f64, cap_hp: bool) -> Vec<Action> {
        let c = self.cfg.clone();
        let mut out = Vec::new();
        if self.brake_check(p, &mut out) {
            return out;
        }
        self.maybe_release_brake(p, c.t2 - c.t2_buffer, &mut out);
        if p > c.t2 && !self.t2cap {
            self.t2cap = true;
            // Aggressive: straight to the deep cap, no gradual step.
            self.set_lp(Some(c.lp_freq_t2_mhz), &mut out);
            if cap_hp {
                self.set_hp(Some(c.lp_freq_t2_mhz), &mut out);
            }
        }
        if self.t2cap && p < c.t2 - c.t2_buffer {
            self.t2cap = false;
            self.set_lp(None, &mut out);
            if cap_hp {
                self.set_hp(None, &mut out);
            }
        }
        // The single-threshold baselines have no deeper cap to try, so
        // "full caps" means the T2 cap itself (its whole class set).
        self.maybe_escalate_to_brake(now_s, p, self.t2cap, &mut out);
        out
    }

    // -- no-cap (brake backstop only) ------------------------------------
    fn tick_nocap(&mut self, p: f64) -> Vec<Action> {
        let mut out = Vec::new();
        if !self.brake_check(p, &mut out) {
            self.maybe_release_brake(p, self.cfg.t2 - self.cfg.t2_buffer, &mut out);
        }
        out
    }

    // -- intent bookkeeping (dedup: only emit on change) ------------------
    fn set_lp(&mut self, mhz: Option<f64>, out: &mut Vec<Action>) {
        if self.intent.lp_cap_mhz != mhz {
            self.intent.lp_cap_mhz = mhz;
            out.push(match mhz {
                Some(m) => Action::CapLp { mhz: m },
                None => Action::UncapLp,
            });
        }
    }

    fn set_hp(&mut self, mhz: Option<f64>, out: &mut Vec<Action>) {
        if self.intent.hp_cap_mhz != mhz {
            self.intent.hp_cap_mhz = mhz;
            out.push(match mhz {
                Some(m) => Action::CapHp { mhz: m },
                None => Action::UncapHp,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(kind: PolicyKind) -> PolicyEngine {
        PolicyEngine::new(kind, PolicyConfig::default())
    }

    /// Test clock: each tick is one minute apart, comfortably past the
    /// 45 s escalation delay, so consecutive ticks can escalate.
    struct Clk(f64);
    impl Clk {
        fn next(&mut self) -> f64 {
            self.0 += 60.0;
            self.0
        }
    }

    #[test]
    fn polca_t1_caps_lp_to_base_clock() {
        let mut e = engine(PolicyKind::Polca);
        let mut c = Clk(0.0);
        assert!(e.tick(c.next(), 0.70).is_empty());
        let acts = e.tick(c.next(), 0.82);
        assert_eq!(acts, vec![Action::CapLp { mhz: 1275.0 }]);
        // steady state: no re-issue
        assert!(e.tick(c.next(), 0.83).is_empty());
    }

    #[test]
    fn polca_t2_escalates_lp_then_hp() {
        let mut e = engine(PolicyKind::Polca);
        let mut c = Clk(0.0);
        let a1 = e.tick(c.next(), 0.90);
        assert_eq!(a1, vec![Action::CapLp { mhz: 1110.0 }]);
        // still above T2 on the next tick -> HP gets capped
        let a2 = e.tick(c.next(), 0.90);
        assert_eq!(a2, vec![Action::CapHp { mhz: 1305.0 }]);
        // and then nothing new
        assert!(e.tick(c.next(), 0.91).is_empty());
        assert_eq!(e.intent().lp_cap_mhz, Some(1110.0));
        assert_eq!(e.intent().hp_cap_mhz, Some(1305.0));
    }

    #[test]
    fn polca_uncap_order_and_hysteresis() {
        let mut e = engine(PolicyKind::Polca);
        let mut c = Clk(0.0);
        e.tick(c.next(), 0.90);
        e.tick(c.next(), 0.90); // LP@1110, HP@1305
        // Drop below T2 but inside the buffer: nothing changes.
        assert!(e.tick(c.next(), 0.86).is_empty());
        // Below T2 - 5%: HP uncaps, LP relaxes to 1275.
        let acts = e.tick(c.next(), 0.83);
        assert!(acts.contains(&Action::UncapHp));
        assert!(acts.contains(&Action::CapLp { mhz: 1275.0 }));
        // Below T1 but inside its buffer: still capped.
        assert!(e.tick(c.next(), 0.78).is_empty());
        // Below T1 - 5%: LP uncaps.
        assert_eq!(e.tick(c.next(), 0.74), vec![Action::UncapLp]);
        assert_eq!(e.intent(), IntentState::default());
    }

    #[test]
    fn polca_brake_on_overload_and_counts() {
        let mut e = engine(PolicyKind::Polca);
        let mut c = Clk(0.0);
        let acts = e.tick(c.next(), 1.02);
        assert!(acts.contains(&Action::Brake));
        assert!(e.is_braked());
        assert_eq!(e.brake_events, 1);
        // Still overloaded: no duplicate brake.
        assert!(!e.tick(c.next(), 1.01).contains(&Action::Brake));
        assert_eq!(e.brake_events, 1);
        // Recovering below T2-buf releases the brake.
        let rel = e.tick(c.next(), 0.80);
        assert!(rel.contains(&Action::ReleaseBrake));
        assert!(!e.is_braked());
    }

    #[test]
    fn polca_no_oscillation_at_threshold_boundary() {
        // Flapping around T1 must not generate cap/uncap churn.
        let mut e = engine(PolicyKind::Polca);
        let mut c = Clk(0.0);
        let mut actions = 0;
        for i in 0..100 {
            let p = if i % 2 == 0 { 0.805 } else { 0.795 };
            actions += e.tick(c.next(), p).len();
        }
        assert_eq!(actions, 1, "only the initial cap should fire");
    }

    #[test]
    fn one_thresh_low_pri_caps_hard_immediately() {
        let mut e = engine(PolicyKind::OneThreshLowPri);
        let mut c = Clk(0.0);
        assert!(e.tick(c.next(), 0.85).is_empty()); // below T2: nothing (no T1!)
        let acts = e.tick(c.next(), 0.90);
        assert_eq!(acts, vec![Action::CapLp { mhz: 1110.0 }]);
        assert_eq!(e.intent().hp_cap_mhz, None);
    }

    #[test]
    fn one_thresh_all_caps_everyone() {
        let mut e = engine(PolicyKind::OneThreshAll);
        let mut c = Clk(0.0);
        let acts = e.tick(c.next(), 0.90);
        assert!(acts.contains(&Action::CapLp { mhz: 1110.0 }));
        assert!(acts.contains(&Action::CapHp { mhz: 1110.0 }));
    }

    #[test]
    fn nocap_only_brakes() {
        let mut e = engine(PolicyKind::NoCap);
        let mut c = Clk(0.0);
        assert!(e.tick(c.next(), 0.95).is_empty());
        assert!(e.tick(c.next(), 0.999).is_empty());
        let acts = e.tick(c.next(), 1.01);
        assert_eq!(acts, vec![Action::Brake]);
        assert_eq!(e.brake_events, 1);
    }

    #[test]
    fn stuck_above_t2_escalates_to_brake_when_enabled() {
        let mut e = engine(PolicyKind::Polca);
        e.escalate_to_brake_after_s = Some(120.0);
        let mut c = Clk(0.0);
        e.tick(c.next(), 0.92); // LP capped
        e.tick(c.next(), 0.92); // HP capped (full cap set engaged)
        // Still above T2, but the 120 s containment clock has not
        // elapsed since full caps — no brake yet.
        assert!(e.tick(c.next(), 0.92).is_empty());
        // Two minutes after full caps with no effect: brake fires even
        // though the reading never crossed 1.0.
        let acts = e.tick(c.next(), 0.92);
        assert_eq!(acts, vec![Action::Brake]);
        assert_eq!(e.brake_events, 1);
        // No duplicate brake while engaged.
        assert!(e.tick(c.next(), 0.92).is_empty());
        // Recovery below T2 − buffer releases and uncaps as usual.
        let rel = e.tick(c.next(), 0.80);
        assert!(rel.contains(&Action::ReleaseBrake));
    }

    #[test]
    fn escalation_clock_resets_when_the_reading_dips_under_t2() {
        // Caps engaged long ago and *working* (p sits in the hysteresis
        // band): a later one-tick excursion above T2 must get the full
        // escalation window, not an instant brake.
        let mut e = engine(PolicyKind::Polca);
        e.escalate_to_brake_after_s = Some(120.0);
        let mut c = Clk(0.0);
        e.tick(c.next(), 0.92); // LP capped
        e.tick(c.next(), 0.92); // HP capped, clock starts
        // The caps bite: p drops into the band (above T2 - buffer, so
        // caps stay engaged) for a long stretch — clock resets.
        for _ in 0..20 {
            assert!(e.tick(c.next(), 0.87).is_empty());
        }
        // Fresh excursion above T2: no brake on the first ticks.
        assert!(e.tick(c.next(), 0.90).is_empty());
        assert!(e.tick(c.next(), 0.90).is_empty());
        assert_eq!(e.brake_events, 0);
        // But a *stuck* excursion still escalates after the window.
        let acts = e.tick(c.next(), 0.90);
        assert_eq!(acts, vec![Action::Brake]);
        assert_eq!(e.brake_events, 1);
    }

    #[test]
    fn escalation_disabled_by_default_never_brakes_below_one() {
        let mut e = engine(PolicyKind::Polca);
        let mut c = Clk(0.0);
        for _ in 0..100 {
            e.tick(c.next(), 0.95);
        }
        assert_eq!(e.brake_events, 0);
        assert!(!e.is_braked());
    }

    #[test]
    fn single_threshold_baselines_also_escalate() {
        for kind in [PolicyKind::OneThreshLowPri, PolicyKind::OneThreshAll] {
            let mut e = engine(kind);
            e.escalate_to_brake_after_s = Some(90.0);
            let mut c = Clk(0.0);
            e.tick(c.next(), 0.92); // T2 cap engaged
            assert!(e.tick(c.next(), 0.92).is_empty()); // 60 s < 90 s
            let acts = e.tick(c.next(), 0.92); // 120 s >= 90 s
            assert!(acts.contains(&Action::Brake), "{kind:?}: {acts:?}");
        }
        // NoCap has no caps whose failure could be observed.
        let mut e = engine(PolicyKind::NoCap);
        e.escalate_to_brake_after_s = Some(90.0);
        let mut c = Clk(0.0);
        for _ in 0..10 {
            e.tick(c.next(), 0.95);
        }
        assert_eq!(e.brake_events, 0);
    }

    #[test]
    fn monotone_power_monotone_strictness() {
        // Property: as the reading rises 0→1.05, the cap state only
        // tightens (never uncaps mid-ascent).
        let mut e = engine(PolicyKind::Polca);
        let mut c = Clk(0.0);
        let mut last_lp = f64::INFINITY;
        let mut last_hp = f64::INFINITY;
        for i in 0..=105 {
            let p = i as f64 / 100.0;
            e.tick(c.next(), p);
            let lp = e.intent().lp_cap_mhz.unwrap_or(f64::INFINITY);
            let hp = e.intent().hp_cap_mhz.unwrap_or(f64::INFINITY);
            assert!(lp <= last_lp, "LP cap loosened on ascent at p={p}");
            assert!(hp <= last_hp, "HP cap loosened on ascent at p={p}");
            last_lp = lp;
            last_hp = hp;
        }
        assert!(e.is_braked());
    }
}
