//! Week-one threshold tuning (§6.2): search the (T1, T2) space and the
//! oversubscription level for the configuration that maximizes added
//! servers while meeting the Table-5 SLOs with zero powerbrakes.
//!
//! This is also the mechanism behind POLCA's long-term reconfigurability
//! (§5.1 "Robustness and configurability"): re-run the tuner on fresh
//! traces when the fleet's models change.

use crate::config::SloConfig;
use crate::exec::{run_batch, ExecConfig};
use crate::policy::engine::PolicyKind;
use crate::simulation::{run_with_impact, SimConfig};

/// Result of evaluating one (T1, T2, added-servers) point.
#[derive(Debug, Clone)]
pub struct TunerPoint {
    /// Lower capping threshold evaluated.
    pub t1: f64,
    /// Upper capping threshold evaluated.
    pub t2: f64,
    /// Added-server fraction evaluated.
    pub added_frac: f64,
    /// HP P50 latency impact at this point.
    pub hp_p50: f64,
    /// HP P99 latency impact.
    pub hp_p99: f64,
    /// LP P50 latency impact.
    pub lp_p50: f64,
    /// LP P99 latency impact.
    pub lp_p99: f64,
    /// Powerbrake engagements at this point.
    pub brakes: u64,
    /// Whether every Table 5 SLO held.
    pub meets_slo: bool,
}

/// Outcome of a full tuner sweep.
#[derive(Debug, Clone)]
pub struct TunerOutcome {
    /// Every evaluated point, in sweep order.
    pub points: Vec<TunerPoint>,
    /// Best (t1, t2, added_frac) meeting SLOs.
    pub best: Option<(f64, f64, f64)>,
}

/// Evaluate one configuration point on a training week.
pub fn evaluate_point(
    base: &SimConfig,
    t1: f64,
    t2: f64,
    added_frac: f64,
    slo: &SloConfig,
) -> TunerPoint {
    let mut cfg = base.clone();
    cfg.policy_kind = PolicyKind::Polca;
    cfg.exp.policy.t1 = t1;
    cfg.exp.policy.t2 = t2;
    cfg.deployed_servers =
        (base.exp.row.num_servers as f64 * (1.0 + added_frac)).round() as usize;
    let (_, impact) = run_with_impact(&cfg);
    TunerPoint {
        t1,
        t2,
        added_frac,
        hp_p50: impact.hp_p50,
        hp_p99: impact.hp_p99,
        lp_p50: impact.lp_p50,
        lp_p99: impact.lp_p99,
        brakes: impact.brake_events,
        meets_slo: impact.meets_slo(slo),
    }
}

/// Sweep (T1,T2) combos × added-server levels (the Fig 13 grid); return
/// every point plus the best SLO-meeting configuration (max added).
/// Grid points are independent paired simulations, so they fan out
/// through the parallel scenario executor by default.
pub fn tune_thresholds(
    base: &SimConfig,
    combos: &[(f64, f64)],
    added_fracs: &[f64],
    slo: &SloConfig,
) -> TunerOutcome {
    tune_thresholds_exec(base, combos, added_fracs, slo, &ExecConfig::default())
}

/// [`tune_thresholds`] with an explicit executor configuration (the
/// `polca tune --serial` reference path). The best-point selection
/// scans the collected grid in sweep order, so the verdict is
/// bit-identical regardless of scheduling.
pub fn tune_thresholds_exec(
    base: &SimConfig,
    combos: &[(f64, f64)],
    added_fracs: &[f64],
    slo: &SloConfig,
    exec: &ExecConfig,
) -> TunerOutcome {
    let grid: Vec<(f64, f64, f64)> = combos
        .iter()
        .flat_map(|&(t1, t2)| added_fracs.iter().map(move |&a| (t1, t2, a)))
        .collect();
    let points =
        run_batch(&grid, exec, |_, &(t1, t2, added)| evaluate_point(base, t1, t2, added, slo));
    let mut best: Option<(f64, f64, f64)> = None;
    for p in &points {
        if p.meets_slo && best.map(|(_, _, a)| p.added_frac > a).unwrap_or(true) {
            best = Some((p.t1, p.t2, p.added_frac));
        }
    }
    TunerOutcome { points, best }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_bit_identical, base_sim_config};

    #[test]
    fn zero_added_meets_slo() {
        let base = base_sim_config(12, 0.05, 9);
        let p = evaluate_point(&base, 0.80, 0.89, 0.0, &SloConfig::default());
        assert!(p.meets_slo, "{p:?}");
        assert_eq!(p.brakes, 0);
    }

    #[test]
    fn sweep_returns_grid_and_best() {
        let base = base_sim_config(12, 0.05, 9);
        let out = tune_thresholds(
            &base,
            &[(0.80, 0.89)],
            &[0.0, 0.25],
            &SloConfig::default(),
        );
        assert_eq!(out.points.len(), 2);
        assert!(out.best.is_some());
        let (_, _, added) = out.best.unwrap();
        assert!(added >= 0.0);
    }

    #[test]
    fn parallel_sweep_is_bit_identical_to_serial() {
        let base = base_sim_config(12, 0.05, 9);
        let combos = [(0.80, 0.89)];
        let added = [0.0, 0.25];
        let slo = SloConfig::default();
        let par = tune_thresholds_exec(&base, &combos, &added, &slo, &ExecConfig::default());
        let ser = tune_thresholds_exec(&base, &combos, &added, &slo, &ExecConfig::serial());
        assert_bit_identical(&par.points, &ser.points, "tuner grid");
        assert_eq!(par.best, ser.best);
    }

    #[test]
    fn best_point_prefers_highest_added_and_breaks_ties_in_sweep_order() {
        let base = base_sim_config(12, 0.05, 9);
        let combos = [(0.75, 0.85), (0.80, 0.89)];
        let slo = SloConfig::default();
        for exec in [ExecConfig::default(), ExecConfig::serial()] {
            // Two rungs over a single level: every point ties on
            // added_frac, so the winner must be the first SLO-meeting
            // point in sweep order, regardless of executor scheduling
            // (the strict `>` in the selection scan never lets a later
            // tie displace an earlier winner).
            let out = tune_thresholds_exec(&base, &combos, &[0.0], &slo, &exec);
            let first =
                out.points.iter().find(|p| p.meets_slo).expect("zero added must meet SLO");
            assert_eq!(out.best, Some((first.t1, first.t2, first.added_frac)));
            assert_eq!(out.best.unwrap(), (0.75, 0.85, 0.0), "tie must go to sweep order");
        }
        // And across levels the highest SLO-meeting added_frac wins:
        // recompute the expected winner with an independent fold over
        // the returned grid.
        let out = tune_thresholds_exec(
            &base,
            &combos,
            &[0.0, 0.10],
            &slo,
            &ExecConfig::default(),
        );
        let expected = out.points.iter().filter(|p| p.meets_slo).fold(
            None::<(f64, f64, f64)>,
            |acc, p| match acc {
                Some((_, _, a)) if p.added_frac <= a => acc,
                _ => Some((p.t1, p.t2, p.added_frac)),
            },
        );
        assert_eq!(out.best, expected);
        let max_ok = out
            .points
            .iter()
            .filter(|p| p.meets_slo)
            .map(|p| p.added_frac)
            .fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(out.best.unwrap().2, max_ok, "best must claim the highest safe level");
    }
}
