//! Power-management policies: POLCA's dual-threshold Algorithm 1, the
//! three baselines of §6.3 (1-Thresh-Low-Pri, 1-Thresh-All, No-cap), and
//! the week-one threshold tuner of §6.2.

pub mod engine;
pub mod tuner;

pub use engine::{Action, PolicyEngine, PolicyKind};
pub use tuner::{tune_thresholds, TunerOutcome};
