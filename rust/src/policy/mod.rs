//! Power-management policies: POLCA's dual-threshold Algorithm 1, the
//! three baselines of §6.3 (1-Thresh-Low-Pri, 1-Thresh-All, No-cap), the
//! week-one threshold tuner of §6.2, and the adaptive outer-loop
//! controller that keeps retuning those knobs online (§5.1).

pub mod adapt;
pub mod engine;
pub mod tuner;

pub use adapt::{AdaptConfig, AdaptController, AdaptReport, RetuneDecision, Verdict, WindowObs};
pub use engine::{Action, PolicyEngine, PolicyKind};
pub use tuner::{tune_thresholds, TunerOutcome};
