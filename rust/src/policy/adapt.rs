//! `policy::adapt` — the adaptive oversubscription controller that
//! closes the provisioning→runtime loop (§5.1 "robustness and
//! configurability", §6.2 week-one tuning made continuous).
//!
//! [`policy::tuner`](crate::policy::tuner) answers "which (T1, T2,
//! added-servers) point is safe?" once, offline, on a training week.
//! This module generalizes that search into an online process: every
//! control window the simulator hands the controller the windowed
//! feedback the faults subsystem already computes — budget-violation
//! seconds, brake engagements, the window's peak normalized row power,
//! and the high-priority SLO slack — and the controller takes at most
//! one bounded hill-climbing step on the same grid the tuner sweeps:
//! the active-server level moves by [`AdaptConfig::level_step`], the
//! (T1, T2) pair moves one rung on [`LADDER`].
//!
//! Safety is structural, not statistical:
//! - **Hysteresis** — a raise needs [`AdaptConfig::hold_windows`]
//!   consecutive calm windows *and* the window peak at least
//!   [`AdaptConfig::raise_margin`] under T2; back-offs are immediate.
//! - **Hard safety clamp** — oversubscription is never raised within
//!   [`AdaptConfig::cooldown_windows`] windows of a budget violation
//!   or brake; an otherwise-eligible raise is *vetoed* (and the veto is
//!   visible in the decision log and the `retune-veto` obs event).
//! - **Bounded actuation** — the level is clamped to
//!   `[min_added, max_added]` and thresholds to the tuner ladder, so a
//!   pathological feedback stream cannot walk the row outside the grid
//!   the offline tuner certifies.
//!
//! The controller is a pure state machine (no RNG, no clock, no I/O):
//! `decide` consumes one [`WindowObs`] and returns one
//! [`RetuneDecision`]. The simulation glue
//! ([`crate::simulation::adapt`]) owns the windows, the actuation, and
//! the event emission, which keeps this logic unit-testable and reusable
//! by a live coordinator.

use crate::config::SloConfig;

/// The (T1, T2) rungs the controller may occupy — the same threshold
/// pairs `polca tune` sweeps (§6.2), ordered from most conservative
/// (caps engage earliest) to most aggressive.
pub const LADDER: [(f64, f64); 3] = [(0.75, 0.85), (0.80, 0.89), (0.85, 0.95)];

/// The rung holding the paper's operating point (T1 = 0.80, T2 = 0.89).
pub const LADDER_DEFAULT: usize = 1;

/// Controller knobs: window cadence, hysteresis depths, and actuation
/// bounds. The scenario layer carries this verbatim in `[adapt]`.
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptConfig {
    /// Control-window length in seconds (default 6 h: long enough for
    /// violation/brake counts to be meaningful, short enough to track
    /// diurnal drift).
    pub window_s: f64,
    /// Calm windows required before a raise is even eligible.
    pub hold_windows: u32,
    /// The hard safety clamp: no raise within this many windows of a
    /// budget violation or brake engagement.
    pub cooldown_windows: u32,
    /// A raise also needs the window's peak normalized power at least
    /// this far under the active T2 (headroom must exist, not merely
    /// "no violation yet").
    pub raise_margin: f64,
    /// Active-server level step per decision (fraction of baseline).
    pub level_step: f64,
    /// Lower bound on the active-server level.
    pub min_added: f64,
    /// Upper bound on the active-server level (further clamped by the
    /// racked hardware at actuation time).
    pub max_added: f64,
    /// Level the controller starts at.
    pub initial_added: f64,
}

impl Default for AdaptConfig {
    fn default() -> Self {
        AdaptConfig {
            window_s: 21_600.0,
            hold_windows: 2,
            cooldown_windows: 3,
            raise_margin: 0.05,
            level_step: 0.05,
            min_added: 0.0,
            max_added: 0.40,
            initial_added: 0.0,
        }
    }
}

/// One control window's feedback signal, as accumulated by the
/// simulation layer between `RetuneCheck` events.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct WindowObs {
    /// Seconds the row spent over the power budget this window.
    pub violation_s: f64,
    /// Powerbrake engagements this window.
    pub brakes: u64,
    /// Max normalized (delayed) row-power reading this window.
    pub peak_norm: f64,
    /// High-priority latency slowdown this window (actual/nominal − 1),
    /// compared against [`SloConfig::hp_p99_impact`] for SLO slack.
    pub hp_slowdown: f64,
}

/// What the controller did with one window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// No knob moved (steady state, or nothing eligible).
    Hold,
    /// One knob moved (level or threshold rung, up or down).
    Apply,
    /// A raise was eligible on the hysteresis terms but blocked by the
    /// post-violation cooldown — the hard safety clamp firing.
    Veto,
}

/// One entry of the retune decision log: the verdict plus the knob
/// state *after* the decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetuneDecision {
    /// Simulation time of the window boundary.
    pub t_s: f64,
    /// What happened.
    pub verdict: Verdict,
    /// Active-server level after the decision.
    pub added: f64,
    /// T1 after the decision.
    pub t1: f64,
    /// T2 after the decision.
    pub t2: f64,
}

/// Controller outcome summary attached to
/// [`crate::metrics::RunReport::adapt`] when the controller ran.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct AdaptReport {
    /// Control windows evaluated.
    pub evals: u64,
    /// Decisions that moved a knob.
    pub applies: u64,
    /// Raises blocked by the safety clamp.
    pub vetoes: u64,
    /// Time-weighted mean active-server level over the horizon.
    pub mean_added: f64,
    /// Level at the end of the run.
    pub final_added: f64,
    /// T1 at the end of the run.
    pub final_t1: f64,
    /// T2 at the end of the run.
    pub final_t2: f64,
    /// Arrivals shed because they landed on a deactivated server.
    pub requests_shed: u64,
    /// The full decision sequence, in window order.
    pub decisions: Vec<RetuneDecision>,
}

/// The pure controller state machine. See the module docs for the
/// decision procedure; [`AdaptController::decide`] is the whole API.
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptController {
    /// The knob set this controller was built with.
    pub cfg: AdaptConfig,
    level: f64,
    ladder_idx: usize,
    calm: u32,
    windows_since_violation: u32,
}

impl AdaptController {
    /// A controller at the config's initial level on the paper rung.
    pub fn new(cfg: AdaptConfig) -> Self {
        let level = cfg.initial_added.clamp(cfg.min_added, cfg.max_added);
        AdaptController {
            cfg,
            level,
            ladder_idx: LADDER_DEFAULT,
            calm: 0,
            // "No violation ever seen": saturated so the first raise is
            // gated only by hold_windows, not a phantom cooldown.
            windows_since_violation: u32::MAX,
        }
    }

    /// Current active-server level (fraction of baseline).
    pub fn level(&self) -> f64 {
        self.level
    }

    /// Current (T1, T2) rung.
    pub fn thresholds(&self) -> (f64, f64) {
        LADDER[self.ladder_idx]
    }

    /// Consume one window of feedback and take at most one knob step.
    /// Pure and deterministic: the same observation sequence always
    /// yields the same decision sequence.
    pub fn decide(&mut self, t_s: f64, obs: &WindowObs, slo: &SloConfig) -> RetuneDecision {
        let verdict = self.step(obs, slo);
        let (t1, t2) = self.thresholds();
        RetuneDecision { t_s, verdict, added: self.level, t1, t2 }
    }

    fn step(&mut self, obs: &WindowObs, slo: &SloConfig) -> Verdict {
        // 1. Unsafe window: violation or brake. Back off immediately
        //    (level first — it sheds load; thresholds second) and arm
        //    the cooldown clamp.
        if obs.violation_s > 0.0 || obs.brakes > 0 {
            self.windows_since_violation = 0;
            self.calm = 0;
            return if self.step_down() { Verdict::Apply } else { Verdict::Hold };
        }
        self.windows_since_violation = self.windows_since_violation.saturating_add(1);

        // 2. Power-safe but the HP SLO is breached: the row is
        //    over-packed for its latency budget — back the level off,
        //    but no cooldown (this is an SLO signal, not a power one).
        if obs.hp_slowdown > slo.hp_p99_impact {
            self.calm = 0;
            return if self.step_down_level() { Verdict::Apply } else { Verdict::Hold };
        }

        // 3. Calm window. A raise needs consecutive calm (hysteresis),
        //    real headroom under the active T2, and an available knob;
        //    the cooldown clamp can still veto it.
        self.calm = self.calm.saturating_add(1);
        let (_, t2) = self.thresholds();
        let headroom = obs.peak_norm < t2 - self.cfg.raise_margin;
        if self.calm >= self.cfg.hold_windows && headroom && self.can_raise() {
            if self.windows_since_violation < self.cfg.cooldown_windows {
                return Verdict::Veto;
            }
            self.raise();
            // A raise spends the calm streak: the next one needs a
            // fresh hold_windows of evidence at the new operating point.
            self.calm = 0;
            return Verdict::Apply;
        }
        Verdict::Hold
    }

    // -- knob mechanics ---------------------------------------------------

    fn can_raise(&self) -> bool {
        self.ladder_idx < LADDER_DEFAULT
            || self.level < self.cfg.max_added - 1e-12
            || self.ladder_idx + 1 < LADDER.len()
    }

    /// One raise step, in priority order: restore a backed-off threshold
    /// rung toward the paper default, then grow the level, then (level
    /// maxed) take the aggressive rung.
    fn raise(&mut self) {
        if self.ladder_idx < LADDER_DEFAULT {
            self.ladder_idx += 1;
        } else if self.level < self.cfg.max_added - 1e-12 {
            self.level = (self.level + self.cfg.level_step).min(self.cfg.max_added);
        } else if self.ladder_idx + 1 < LADDER.len() {
            self.ladder_idx += 1;
        }
    }

    /// One back-off step: level first, threshold rung once the level is
    /// floored. Returns whether anything moved.
    fn step_down(&mut self) -> bool {
        if self.step_down_level() {
            true
        } else if self.ladder_idx > 0 {
            self.ladder_idx -= 1;
            true
        } else {
            false
        }
    }

    fn step_down_level(&mut self) -> bool {
        if self.level > self.cfg.min_added + 1e-12 {
            self.level = (self.level - self.cfg.level_step).max(self.cfg.min_added);
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn calm(peak: f64) -> WindowObs {
        WindowObs { violation_s: 0.0, brakes: 0, peak_norm: peak, hp_slowdown: 0.0 }
    }

    fn violated() -> WindowObs {
        WindowObs { violation_s: 30.0, brakes: 1, peak_norm: 1.01, hp_slowdown: 0.0 }
    }

    fn ctl() -> AdaptController {
        AdaptController::new(AdaptConfig::default())
    }

    #[test]
    fn starts_on_the_paper_rung_at_the_initial_level() {
        let c = ctl();
        assert_eq!(c.thresholds(), (0.80, 0.89));
        assert_eq!(c.level(), 0.0);
        let mut c2 = AdaptController::new(AdaptConfig {
            initial_added: 0.9, // clamped into [min, max]
            ..AdaptConfig::default()
        });
        assert_eq!(c2.level(), 0.40);
        // Level maxed: the first raise takes the aggressive rung.
        let slo = SloConfig::default();
        c2.decide(0.0, &calm(0.5), &slo);
        let d = c2.decide(1.0, &calm(0.5), &slo);
        assert_eq!(d.verdict, Verdict::Apply);
        assert_eq!((d.t1, d.t2), (0.85, 0.95));
    }

    #[test]
    fn raise_needs_hold_windows_of_calm() {
        let mut c = ctl();
        let slo = SloConfig::default();
        // First calm window: calm streak 1 < hold_windows 2 — hold.
        assert_eq!(c.decide(0.0, &calm(0.5), &slo).verdict, Verdict::Hold);
        // Second: eligible, no violation ever — apply (level +5%).
        let d = c.decide(1.0, &calm(0.5), &slo);
        assert_eq!(d.verdict, Verdict::Apply);
        assert!((d.added - 0.05).abs() < 1e-12);
        // The raise spent the streak: the next window holds again.
        assert_eq!(c.decide(2.0, &calm(0.5), &slo).verdict, Verdict::Hold);
    }

    #[test]
    fn no_raise_without_headroom_under_t2() {
        let mut c = ctl();
        let slo = SloConfig::default();
        // Peak within raise_margin of T2=0.89: calm, but never a raise.
        for i in 0..10 {
            assert_eq!(c.decide(i as f64, &calm(0.87), &slo).verdict, Verdict::Hold);
        }
        assert_eq!(c.level(), 0.0);
    }

    #[test]
    fn violation_backs_off_and_clamps_raises_for_cooldown_windows() {
        let cfg = AdaptConfig { initial_added: 0.10, ..AdaptConfig::default() };
        let mut c = AdaptController::new(cfg);
        let slo = SloConfig::default();
        // Violation window: immediate back-off 0.10 -> 0.05.
        let d = c.decide(0.0, &violated(), &slo);
        assert_eq!(d.verdict, Verdict::Apply);
        assert!((d.added - 0.05).abs() < 1e-12);
        // Calm again; raise becomes hysteresis-eligible on window 2 but
        // the safety clamp vetoes until cooldown_windows (3) have passed.
        assert_eq!(c.decide(1.0, &calm(0.5), &slo).verdict, Verdict::Hold);
        assert_eq!(c.decide(2.0, &calm(0.5), &slo).verdict, Verdict::Veto);
        // Third calm window: windows_since_violation reaches 3 — allowed.
        let d = c.decide(3.0, &calm(0.5), &slo);
        assert_eq!(d.verdict, Verdict::Apply);
        assert!((d.added - 0.10).abs() < 1e-12);
    }

    #[test]
    fn repeated_violations_walk_down_the_ladder_after_the_level_floors() {
        let cfg = AdaptConfig { initial_added: 0.05, ..AdaptConfig::default() };
        let mut c = AdaptController::new(cfg);
        let slo = SloConfig::default();
        c.decide(0.0, &violated(), &slo); // level 0.05 -> 0.00
        assert_eq!(c.thresholds(), (0.80, 0.89));
        let d = c.decide(1.0, &violated(), &slo); // level floored: rung down
        assert_eq!(d.verdict, Verdict::Apply);
        assert_eq!((d.t1, d.t2), (0.75, 0.85));
        // Fully backed off: further violations can only hold.
        assert_eq!(c.decide(2.0, &violated(), &slo).verdict, Verdict::Hold);
        // Recovery restores the rung toward the default before growing
        // the level again: calm, then a clamped (vetoed) raise, then
        // the rung restore once the cooldown has passed.
        assert_eq!(c.decide(3.0, &calm(0.5), &slo).verdict, Verdict::Hold);
        assert_eq!(c.decide(4.0, &calm(0.5), &slo).verdict, Verdict::Veto);
        let d = c.decide(5.0, &calm(0.5), &slo);
        assert_eq!(d.verdict, Verdict::Apply);
        assert_eq!((d.t1, d.t2), (0.80, 0.89));
        assert_eq!(d.added, 0.0, "rung restore must not touch the level");
    }

    #[test]
    fn hp_slo_breach_sheds_level_without_arming_the_cooldown() {
        let cfg = AdaptConfig { initial_added: 0.10, ..AdaptConfig::default() };
        let mut c = AdaptController::new(cfg);
        let slo = SloConfig::default();
        let slow = WindowObs { hp_slowdown: 0.10, peak_norm: 0.5, ..WindowObs::default() };
        let d = c.decide(0.0, &slow, &slo);
        assert_eq!(d.verdict, Verdict::Apply);
        assert!((d.added - 0.05).abs() < 1e-12);
        // No power violation occurred, so the next eligible raise is
        // not vetoed (only held for the hysteresis streak).
        assert_eq!(c.decide(1.0, &calm(0.5), &slo).verdict, Verdict::Hold);
        assert_eq!(c.decide(2.0, &calm(0.5), &slo).verdict, Verdict::Apply);
    }

    #[test]
    fn level_and_thresholds_stay_inside_the_grid_on_any_feedback() {
        // Property: an adversarial observation stream can never walk the
        // controller outside [min_added, max_added] x LADDER.
        let slo = SloConfig::default();
        crate::testing::check_default(
            "adapt-bounded",
            |r| {
                (0..40)
                    .map(|_| WindowObs {
                        violation_s: if r.bool(0.3) { r.range_f64(0.0, 60.0) } else { 0.0 },
                        brakes: if r.bool(0.2) { 1 } else { 0 },
                        peak_norm: r.range_f64(0.3, 1.05),
                        hp_slowdown: r.range_f64(0.0, 0.2),
                    })
                    .collect::<Vec<_>>()
            },
            |seq| {
                let mut c = ctl();
                for (i, obs) in seq.iter().enumerate() {
                    let d = c.decide(i as f64, obs, &slo);
                    if !(0.0..=0.40).contains(&d.added) {
                        return Err(format!("level {} escaped the grid", d.added));
                    }
                    if !LADDER.contains(&(d.t1, d.t2)) {
                        return Err(format!("thresholds ({}, {}) off the ladder", d.t1, d.t2));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn decision_sequence_is_a_pure_function_of_the_observation_sequence() {
        let slo = SloConfig::default();
        let seq: Vec<WindowObs> = (0..30)
            .map(|i| if i % 7 == 3 { violated() } else { calm(0.4 + 0.01 * i as f64) })
            .collect();
        let run = || {
            let mut c = ctl();
            seq.iter()
                .enumerate()
                .map(|(i, o)| c.decide(i as f64, o, &slo))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
