//! L3 bench: the POLCA policy engine's per-tick cost (it runs on every
//! telemetry sample, so it must be well under a microsecond) and the
//! telemetry buffer's record/read path.

use polca::benchkit::{bench, black_box, BenchConfig};
use polca::cluster::telemetry::TelemetryBuffer;
use polca::config::PolicyConfig;
use polca::policy::engine::{PolicyEngine, PolicyKind};

fn main() {
    let cfg = BenchConfig::default();

    let r = bench("policy_tick_1k_mixed_readings", &cfg, 1000.0, || {
        let mut e = PolicyEngine::new(PolicyKind::Polca, PolicyConfig::default());
        for i in 0..1000 {
            // sweep through all regimes: idle, T1, T2, overload, recovery
            let p = 0.5 + 0.6 * ((i as f64 / 120.0).sin().abs());
            black_box(e.tick(i as f64 * 2.0, p));
        }
    });
    println!("{}", r.report());

    let r = bench("telemetry_record_and_visible_1k", &cfg, 1000.0, || {
        let mut tb = TelemetryBuffer::new(2.0, 3600.0);
        for i in 0..1000 {
            tb.record(i as f64 * 2.0, 0.7);
            black_box(tb.visible_at(i as f64 * 2.0));
        }
    });
    println!("{}", r.report());

    let r = bench("telemetry_spike_stats_1800_samples", &cfg, 1.0, || {
        let mut tb = TelemetryBuffer::new(2.0, 3600.0);
        for i in 0..1800 {
            tb.record(i as f64 * 2.0, 0.7 + (i % 13) as f64 * 0.01);
        }
        black_box(tb.spike_stats(&[2.0, 5.0, 40.0]));
    });
    println!("{}", r.report());
}
