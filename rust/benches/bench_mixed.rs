//! Mixed-row bench: event throughput of the training-job driver vs the
//! inference-only simulator, and the colocation mix in between. The
//! training driver schedules one event per waveform phase per *job*
//! (not per server), so pure-training rows should push more simulated
//! seconds per wall second than inference rows despite the synchronized
//! per-server power refreshes.

use polca::benchkit::{bench, black_box, BenchConfig};
use polca::policy::engine::PolicyKind;
use polca::simulation::{run, MixedRowConfig, SimConfig};

fn main() {
    let cfg = BenchConfig::slow();

    for (name, frac) in [("inference", 0.0), ("half-training", 0.5), ("training", 1.0)] {
        let mut sim_cfg = SimConfig::default();
        sim_cfg.weeks = 1.0 / 7.0; // one simulated day
        sim_cfg.deployed_servers = 40;
        sim_cfg.exp.seed = 3;
        sim_cfg.policy_kind = PolicyKind::Polca;
        sim_cfg.mixed = Some(MixedRowConfig { training_fraction: frac, ..Default::default() });
        let events = run(&sim_cfg).events as f64;
        let r = bench(&format!("mixed_row_1day_40srv_{name}"), &cfg, events, || {
            black_box(run(&sim_cfg));
        });
        println!("{}  [= events/s]", r.report());
    }
}
