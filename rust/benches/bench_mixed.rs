//! Mixed-row bench: event throughput of the training-job driver vs the
//! inference-only simulator, and the colocation mix in between. The
//! training driver schedules one event per waveform phase per *job*
//! (not per server), so pure-training rows should push more simulated
//! seconds per wall second than inference rows despite the synchronized
//! per-server power refreshes.

use polca::benchkit::{bench, black_box, BenchConfig};
use polca::cluster::telemetry::TelemetryBuffer;
use polca::policy::engine::PolicyKind;
use polca::simulation::{run, MixedRowConfig, SimConfig};

/// ISSUE-3 satellite before/after: `TelemetryBuffer::values()` used to
/// materialize a fresh `Vec` inside every `spike_stats` call; the
/// statistics now run off `iter_values()`/a caller-owned scratch
/// buffer. `alloc_per_call` measures the old shape (fresh Vec each
/// call via `values()`), `scratch_reuse` the new one — record both
/// when running on real hardware to document the win.
fn bench_telemetry_stats(cfg: &BenchConfig) {
    // One simulated day of 2 s PDU samples (43 200 points).
    let mut tb = TelemetryBuffer::new(2.0, 90_000.0);
    for i in 0..43_200u32 {
        // Deterministic sawtooth with diurnal drift — shape is irrelevant,
        // only the buffer length matters to the allocation cost.
        let x = 0.55 + 0.25 * ((i % 97) as f64 / 97.0) + 0.1 * ((i / 1800) % 24) as f64 / 24.0;
        tb.record(i as f64 * 2.0, x);
    }
    // Both sides compute the identical spike statistics; the only
    // difference is where the contiguous sample copy lives — a fresh
    // Vec per call (the pre-fix `values()` shape, which `spike_stats`
    // reproduces internally) vs one reused scratch buffer.
    let windows = [2.0, 5.0, 40.0];
    let r = bench("telemetry_stats_alloc_per_call", cfg, 1.0, || {
        black_box(tb.spike_stats(&windows));
    });
    println!("{}  [= calls/s]", r.report());
    let mut scratch = Vec::new();
    let r = bench("telemetry_stats_scratch_reuse", cfg, 1.0, || {
        black_box(tb.spike_stats_with(&windows, &mut scratch));
    });
    println!("{}  [= calls/s]", r.report());
}

fn main() {
    let cfg = BenchConfig::slow();
    bench_telemetry_stats(&cfg);

    for (name, frac) in [("inference", 0.0), ("half-training", 0.5), ("training", 1.0)] {
        let mut sim_cfg = SimConfig::default();
        sim_cfg.weeks = 1.0 / 7.0; // one simulated day
        sim_cfg.deployed_servers = 40;
        sim_cfg.exp.seed = 3;
        sim_cfg.policy_kind = PolicyKind::Polca;
        sim_cfg.mixed = Some(MixedRowConfig { training_fraction: frac, ..Default::default() });
        let events = run(&sim_cfg).events as f64;
        let r = bench(&format!("mixed_row_1day_40srv_{name}"), &cfg, events, || {
            black_box(run(&sim_cfg));
        });
        println!("{}  [= events/s]", r.report());
    }
}
