//! End-to-end bench: time to regenerate each paper figure/table at Quick
//! depth — one bench row per experiment, mirroring the DESIGN.md §4
//! per-experiment index. (Also a smoke test that every generator runs.)

use polca::experiments::{all_ids, run_experiment, Depth};
use std::time::Instant;

fn main() {
    let mut total = 0.0;
    for id in all_ids() {
        let t = Instant::now();
        let out = run_experiment(id, Depth::Quick, 1).expect(id);
        let dt = t.elapsed().as_secs_f64();
        total += dt;
        println!(
            "{:<8} {:>8.2}s  ({} tables, {} csvs)",
            id,
            dt,
            out.tables.len(),
            out.csvs.len()
        );
    }
    println!("{:<8} {total:>8.2}s", "TOTAL");
}
