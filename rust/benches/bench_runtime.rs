//! L2/L3 bridge bench: PJRT execution latency of the AOT artifacts —
//! prefill per bucket and one batched decode step. These are the real
//! request-path costs of the serving node. Skips (with a note) when
//! artifacts are absent.

use polca::benchkit::{bench, black_box, BenchConfig};
use polca::runtime::Engine;
use std::path::PathBuf;

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("bench_runtime: artifacts/ missing — run `make artifacts` first");
        return;
    }
    let engine = Engine::load(&dir).expect("engine load");
    let cfg = BenchConfig::slow();

    for bucket in engine.buckets() {
        let tokens: Vec<i32> = (0..bucket as i32).map(|i| (i * 13 + 1) % 512).collect();
        let r = bench(&format!("prefill_s{bucket}"), &cfg, tokens.len() as f64, || {
            let kv = engine.empty_kv().unwrap();
            let out = engine.prefill(kv, &tokens, tokens.len(), 0).unwrap();
            black_box(out.0[0]);
        });
        println!("{}  [= prompt tok/s]", r.report());
    }

    let b = engine.manifest.model.batch_slots;
    let tokens = vec![7i32; b];
    let pos: Vec<i32> = (0..b as i32).map(|i| i + 4).collect();
    let r = bench(&format!("decode_step_b{b}"), &cfg, b as f64, || {
        // The empty_kv rebuild is part of the measured host-roundtrip
        // story (the KV cache travels host<->device each step; see
        // EXPERIMENTS.md §Perf).
        let kv = engine.empty_kv().unwrap();
        let out = engine.decode(kv, &tokens, &pos).unwrap();
        black_box(out.0[0]);
    });
    println!("{}  [= output tok/s]", r.report());
}
