//! L3 bench: discrete-event simulator throughput (events/s) — the §Perf
//! headline for the evaluation vehicle — plus the DES queue in isolation.

use polca::benchkit::{bench, black_box, BenchConfig};
use polca::policy::engine::PolicyKind;
use polca::sim::EventQueue;
use polca::simulation::{run, SimConfig};

fn main() {
    let cfg = BenchConfig::default();

    // Raw event-queue churn: schedule + pop cycles.
    let r = bench("event_queue_schedule_pop_1k", &cfg, 1000.0, || {
        let mut q = EventQueue::new();
        for i in 0..1000u64 {
            q.schedule_at(i * 7 % 997, i);
        }
        while let Some(x) = q.pop() {
            black_box(x);
        }
    });
    println!("{}", r.report());

    // One simulated day of the full cluster model, per policy.
    for (name, kind) in [("polca", PolicyKind::Polca), ("nocap", PolicyKind::NoCap)] {
        let mut sim_cfg = SimConfig::default();
        sim_cfg.weeks = 1.0 / 7.0;
        sim_cfg.deployed_servers = 52;
        sim_cfg.exp.seed = 3;
        sim_cfg.policy_kind = kind;
        let events = run(&sim_cfg).events as f64;
        let r = bench(
            &format!("cluster_sim_1day_52srv_{name}"),
            &BenchConfig::slow(),
            events,
            || {
                black_box(run(&sim_cfg));
            },
        );
        println!("{}  [= events/s]", r.report());
    }
}
