//! L3 bench: discrete-event simulator throughput (events/s) — the §Perf
//! headline for the evaluation vehicle — plus the DES queue in
//! isolation, a per-layer hot-path breakdown (queue ops old vs new,
//! power-model eval direct vs memo-hit, RNG/sampling, settlement
//! proxy), the scenario-executor speedup (a quick sweep batch,
//! serial vs parallel), the traced-vs-untraced recording overhead
//! (`trace_overhead_frac`), the adaptive-controller overhead
//! (`adapt_overhead_frac`, `retune_evals_per_s`), and a
//! profiled-batch utilization snapshot,
//! recorded to `BENCH_sim.json` so the perf trajectory of the
//! matrix/sweep/trace paths is tracked across PRs.
//! `docs/PERFORMANCE.md` explains how to read each key.
//!
//! `--smoke` (the CI mode) shrinks every measurement budget so the run
//! finishes in seconds while still writing a complete BENCH_sim.json.

use std::collections::HashMap;
use std::time::Duration;

use polca::benchkit::{bench, black_box, BenchConfig};
use polca::exec::{run_batch, run_batch_profiled, ExecConfig};
use polca::obs::{batch_stats, Recorder, RecorderConfig};
use polca::policy::adapt::AdaptConfig;
use polca::policy::engine::PolicyKind;
use polca::power::gpu::{CapMode, Phase};
use polca::power::server::ServerPowerModel;
use polca::sim::reference::ReferenceQueue;
use polca::sim::EventQueue;
use polca::simulation::{run, run_observed, SimConfig};
use polca::util::hash::FxBuildHasher;
use polca::util::json::Json;
use polca::util::rng::Rng;
use polca::workload::arrivals::ArrivalProcess;
use polca::workload::spec::{sample_request, table4};

/// One item of the sweep batch the executor benchmark fans out: the
/// quick-matrix shape (small row, short horizon, varying policy/seed).
fn sweep_batch() -> Vec<SimConfig> {
    let policies = PolicyKind::all();
    (0..8u64)
        .map(|i| {
            let mut cfg = SimConfig::default();
            cfg.exp.row.num_servers = 12;
            cfg.deployed_servers = 16;
            cfg.weeks = 0.01;
            cfg.exp.seed = 100 + i;
            cfg.power_scale = 1.35;
            cfg.policy_kind = policies[(i as usize) % policies.len()];
            cfg
        })
        .collect()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cfg = if smoke {
        BenchConfig {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            min_iters: 3,
            max_iters: 1000,
        }
    } else {
        BenchConfig::default()
    };
    let slow_cfg = if smoke {
        BenchConfig {
            warmup: Duration::from_millis(0),
            measure: Duration::from_millis(500),
            min_iters: 1,
            max_iters: 100,
        }
    } else {
        BenchConfig::slow()
    };

    // Raw event-queue churn: schedule + pop cycles, new 4-ary heap vs
    // the retained pre-rewrite binary heap (ISSUE 10 breakdown: the
    // same workload through both, so the queue win is isolated from
    // every other change).
    let queue_r = bench("event_queue_schedule_pop_1k", &cfg, 1000.0, || {
        let mut q = EventQueue::new();
        for i in 0..1000u64 {
            q.schedule_at(i * 7 % 997, i);
        }
        while let Some(x) = q.pop() {
            black_box(x);
        }
    });
    println!("{}", queue_r.report());
    let queue_ref_r = bench("event_queue_reference_schedule_pop_1k", &cfg, 1000.0, || {
        let mut q = ReferenceQueue::new();
        for i in 0..1000u64 {
            q.schedule_at(i * 7 % 997, i);
        }
        while let Some(x) = q.pop() {
            black_box(x);
        }
    });
    println!("{}  [old binary heap]", queue_ref_r.report());

    // Hot-path breakdown: the ingredient costs behind one simulated
    // event, each measured in isolation at the public API (ISSUE 10).
    //
    // Power-model eval, direct: what every refresh_power paid before
    // the exact-input memo.
    let power_model = ServerPowerModel::default();
    let eval_inputs: Vec<(Phase, CapMode)> = {
        let phases = [
            Phase::Idle,
            Phase::Token { batch: 1.0 },
            Phase::Prompt { total_input: 512.0 },
            Phase::Prompt { total_input: 4096.0 },
        ];
        let caps = [CapMode::None, CapMode::FreqCap { mhz: 1110.0 }];
        phases.iter().flat_map(|&p| caps.iter().map(move |&c| (p, c))).collect()
    };
    let n_evals = eval_inputs.len() as f64 * 125.0;
    let eval_r = bench("power_eval_direct_1k", &cfg, n_evals, || {
        for _ in 0..125 {
            for &(p, c) in &eval_inputs {
                black_box(power_model.server_power_w(p, c, false));
            }
        }
    });
    println!("{}  [= evals/s]", eval_r.report());
    // Power-model eval, memo hit: the FxHash table lookup that replaces
    // the direct eval on the (dominant) warm path — same key shape as
    // simulation::powermemo.
    let mut memo: HashMap<(u8, u64, u64), f64, FxBuildHasher> = HashMap::default();
    let keys: Vec<(u8, u64, u64)> = eval_inputs
        .iter()
        .map(|&(p, c)| {
            let (tag, pb) = match p {
                Phase::Idle => (0u8, 0u64),
                Phase::Token { batch } => (1, batch.to_bits()),
                Phase::Prompt { total_input } => (2, total_input.to_bits()),
            };
            let cb = match c {
                CapMode::None => u64::MAX,
                CapMode::FreqCap { mhz } => mhz.to_bits(),
                CapMode::PowerCap { frac_of_tdp } => frac_of_tdp.to_bits(),
            };
            (tag, pb, cb)
        })
        .collect();
    for (&(p, c), &k) in eval_inputs.iter().zip(&keys) {
        memo.insert(k, power_model.server_power_w(p, c, false));
    }
    let memo_r = bench("power_eval_memo_hit_1k", &cfg, n_evals, || {
        for _ in 0..125 {
            for k in &keys {
                black_box(memo.get(k));
            }
        }
    });
    println!("{}  [= hits/s]", memo_r.report());
    // RNG/sampling: the per-arrival work (one request sample + the next
    // arrival time of a diurnal thinned-Poisson stream).
    let specs = table4();
    let mut sample_rng = Rng::new(42);
    let sample_r = bench("rng_sample_request_1k", &cfg, 1000.0, || {
        for i in 0..1000usize {
            black_box(sample_request(&specs[i % specs.len()], &mut sample_rng));
        }
    });
    println!("{}  [= samples/s]", sample_r.report());
    let mut arrivals = ArrivalProcess::new(0.5, Rng::new(7));
    let mut arr_t = 0.0;
    let arrival_r = bench("rng_arrival_next_1k", &cfg, 1000.0, || {
        for _ in 0..1000 {
            arr_t = black_box(arrivals.next_after(arr_t));
        }
    });
    println!("{}  [= draws/s]", arrival_r.report());
    // Settlement proxy: the energy accumulator settles on every power
    // change and telemetry tick, inseparable from refresh_power at the
    // public surface — so its trajectory is tracked as the events/s
    // delta when the run additionally settles + records a dense power
    // series (one sample a minute) vs none.
    let mut settle_base = SimConfig::default();
    settle_base.exp.row.num_servers = 12;
    settle_base.deployed_servers = 16;
    settle_base.weeks = 0.02;
    settle_base.exp.seed = 9;
    settle_base.power_scale = 1.35;
    let mut settle_dense = settle_base.clone();
    settle_dense.series_sample_s = 60.0;
    let base_events = run(&settle_base).events as f64;
    let dense_events = run(&settle_dense).events as f64;
    let settle_base_r = bench("sim_quickrow_no_series", &cfg, base_events, || {
        black_box(run(&settle_base));
    });
    println!("{}  [= events/s]", settle_base_r.report());
    let settle_dense_r = bench("sim_quickrow_series_60s", &cfg, dense_events, || {
        black_box(run(&settle_dense));
    });
    println!("{}  [= events/s]", settle_dense_r.report());
    let settlement_series_delta_frac =
        1.0 - settle_dense_r.throughput() / settle_base_r.throughput();
    println!(
        "settlement/series overhead: {:.1}% ({:.0} -> {:.0} events/s with 60 s sampling)",
        settlement_series_delta_frac * 100.0,
        settle_base_r.throughput(),
        settle_dense_r.throughput()
    );

    // One simulated day of the full cluster model, per policy.
    let mut sim_events_per_s = Vec::new();
    for (name, kind) in [("polca", PolicyKind::Polca), ("nocap", PolicyKind::NoCap)] {
        let mut sim_cfg = SimConfig::default();
        sim_cfg.weeks = if smoke { 0.02 } else { 1.0 / 7.0 };
        sim_cfg.deployed_servers = 52;
        sim_cfg.exp.seed = 3;
        sim_cfg.policy_kind = kind;
        let events = run(&sim_cfg).events as f64;
        let r = bench(&format!("cluster_sim_1day_52srv_{name}"), &slow_cfg, events, || {
            black_box(run(&sim_cfg));
        });
        println!("{}  [= events/s]", r.report());
        sim_events_per_s.push((name, r.throughput()));
    }

    // Scenario-executor speedup: the quick-sweep batch, serial vs
    // parallel (the hot path behind `polca faults matrix` and the
    // policy/mixed sweeps since ISSUE 5).
    let batch = sweep_batch();
    let runs = batch.len() as f64;
    let serial_r = bench(&format!("sweep_batch_{}x_serial", batch.len()), &slow_cfg, runs, || {
        black_box(run_batch(&batch, &ExecConfig::serial(), |_, c| run(c)));
    });
    println!("{}  [= runs/s]", serial_r.report());
    let parallel_r =
        bench(&format!("sweep_batch_{}x_parallel", batch.len()), &slow_cfg, runs, || {
            black_box(run_batch(&batch, &ExecConfig::default(), |_, c| run(c)));
        });
    println!("{}  [= runs/s]", parallel_r.report());
    let speedup = parallel_r.throughput() / serial_r.throughput();
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    println!(
        "executor speedup: {speedup:.2}x on {threads} hardware threads \
         ({:.2} -> {:.2} runs/s)",
        serial_r.throughput(),
        parallel_r.throughput()
    );

    // Trace overhead (ISSUE 6): the same one-day simulation with a live
    // Recorder attached — what observing costs when someone IS watching.
    // (The off path is pinned elsewhere: golden tests prove the
    // NoopObserver simulator is bit-identical to the pre-trace code.)
    let mut traced_cfg = SimConfig::default();
    traced_cfg.weeks = if smoke { 0.02 } else { 1.0 / 7.0 };
    traced_cfg.deployed_servers = 52;
    traced_cfg.exp.seed = 3;
    traced_cfg.policy_kind = PolicyKind::Polca;
    let traced_events = run(&traced_cfg).events as f64;
    let traced_r = bench("cluster_sim_1day_52srv_polca_traced", &slow_cfg, traced_events, || {
        let mut rec = Recorder::new(RecorderConfig::default());
        black_box(run_observed(&traced_cfg, &mut rec));
        black_box(rec);
    });
    println!("{}  [= events/s]", traced_r.report());
    let untraced = sim_events_per_s[0].1; // ("polca", events/s) measured above
    let trace_overhead_frac = 1.0 - traced_r.throughput() / untraced;
    println!(
        "trace overhead: {:.1}% ({:.0} -> {:.0} events/s with a Recorder attached)",
        trace_overhead_frac * 100.0,
        untraced,
        traced_r.throughput()
    );

    // Adaptive-controller overhead (ISSUE 8): the same one-day row with
    // the retune loop armed — a fast 30-minute window so the horizon
    // holds many control windows. Throughput is compared against the
    // unadapted polca run above (same row shape, same seed), so
    // `adapt_overhead_frac` is what closing the provisioning→runtime
    // loop costs per event; `retune_evals_per_s` is the controller's
    // own decision rate.
    let mut adapt_cfg = traced_cfg.clone();
    adapt_cfg.adapt = Some(AdaptConfig {
        window_s: 1800.0,
        initial_added: 0.10,
        max_added: 0.30,
        ..Default::default()
    });
    let probe = run(&adapt_cfg);
    let adapt_events = probe.events as f64;
    let adapt_summary = probe.adapt.expect("armed controller must report");
    let adapt_r = bench("cluster_sim_1day_52srv_polca_adaptive", &slow_cfg, adapt_events, || {
        black_box(run(&adapt_cfg));
    });
    println!("{}  [= events/s]", adapt_r.report());
    let retune_evals_per_s =
        adapt_r.throughput() * adapt_summary.evals as f64 / adapt_events.max(1.0);
    let adapt_overhead_frac = 1.0 - adapt_r.throughput() / untraced;
    println!(
        "adapt overhead: {:.1}% ({:.0} retune evals/s; {} evals / {} applies / {} \
         vetoes over the horizon)",
        adapt_overhead_frac * 100.0,
        retune_evals_per_s,
        adapt_summary.evals,
        adapt_summary.applies,
        adapt_summary.vetoes
    );

    // Profiled-batch utilization: run_batch_profiled's wall-clock spans
    // folded into a lane-packing profile. One shot, not a bench loop —
    // the numbers are wall-clock and vary; the trajectory is what CI
    // tracks.
    let (outs, spans) = run_batch_profiled(&batch, &ExecConfig::default(), |_, c| run(c));
    black_box(outs);
    let profile = batch_stats(&spans, threads.min(batch.len()));
    println!(
        "profiled batch: {} items, {:.3}s wall, {:.0}% busy across {} workers",
        profile.items,
        profile.wall_s,
        profile.busy_frac * 100.0,
        profile.workers
    );

    // Record the trajectory: BENCH_sim.json at the workspace root.
    let doc = Json::obj(vec![
        ("smoke", Json::Bool(smoke)),
        ("hardware_threads", Json::Num(threads as f64)),
        ("event_queue_ops_per_s", Json::Num(queue_r.throughput())),
        ("event_queue_ref_ops_per_s", Json::Num(queue_ref_r.throughput())),
        ("power_eval_direct_per_s", Json::Num(eval_r.throughput())),
        ("power_eval_memo_hit_per_s", Json::Num(memo_r.throughput())),
        ("rng_sample_request_per_s", Json::Num(sample_r.throughput())),
        ("rng_arrival_next_per_s", Json::Num(arrival_r.throughput())),
        ("settlement_series_delta_frac", Json::num(settlement_series_delta_frac)),
        (
            "sim_events_per_s",
            Json::obj(
                sim_events_per_s.iter().map(|(n, v)| (*n, Json::Num(*v))).collect::<Vec<_>>(),
            ),
        ),
        ("sweep_batch_runs", Json::Num(runs)),
        ("sweep_runs_per_s_serial", Json::Num(serial_r.throughput())),
        ("sweep_runs_per_s_parallel", Json::Num(parallel_r.throughput())),
        ("sweep_parallel_speedup", Json::Num(speedup)),
        ("sim_events_per_s_traced", Json::num(traced_r.throughput())),
        ("trace_overhead_frac", Json::num(trace_overhead_frac)),
        ("sim_events_per_s_adaptive", Json::num(adapt_r.throughput())),
        ("retune_evals_per_s", Json::num(retune_evals_per_s)),
        ("adapt_overhead_frac", Json::num(adapt_overhead_frac)),
        ("profiled_batch_wall_s", Json::num(profile.wall_s)),
        ("profiled_batch_busy_frac", Json::num(profile.busy_frac)),
    ]);
    let path = "BENCH_sim.json";
    match std::fs::write(path, doc.to_pretty() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
