//! Fleet bench: parallel vs serial site evaluation — the speedup that
//! makes the capacity planner's binary search practical at 16 clusters.

use std::time::Duration;

use polca::benchkit::{bench, black_box, BenchConfig};
use polca::fleet::parallel::{run_site, SiteRunConfig};
use polca::fleet::site::SiteSpec;
use polca::policy::engine::PolicyKind;

fn main() {
    let cfg = BenchConfig {
        warmup: Duration::from_millis(0),
        measure: Duration::from_secs(6),
        min_iters: 2,
        max_iters: 1000,
    };

    for n_clusters in [4usize, 16] {
        let site = SiteSpec::demo(n_clusters);
        let mut results = Vec::new();
        for (name, parallel) in [("serial", false), ("parallel", true)] {
            let rc = SiteRunConfig {
                weeks: 0.01,
                seed: 3,
                sample_s: 120.0,
                parallel,
                ..Default::default()
            };
            let r = bench(
                &format!("site_{n_clusters}cluster_polca_{name}"),
                &cfg,
                n_clusters as f64,
                || {
                    black_box(run_site(&site, PolicyKind::Polca, &rc));
                },
            );
            println!("{}  [= clusters/s]", r.report());
            results.push(r);
        }
        let speedup = results[0].mean.as_secs_f64() / results[1].mean.as_secs_f64();
        println!("site_{n_clusters}cluster speedup parallel/serial: {speedup:.2}x");
    }
}
