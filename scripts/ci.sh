#!/usr/bin/env bash
# CI gate for the POLCA reproduction: format, lint, build, test.
#
#   scripts/ci.sh            # run everything, fail on the first gate
#   CI_SKIP_FMT=1 ...        # skip a gate (fmt | clippy) when the
#   CI_SKIP_CLIPPY=1 ...     # component is not installed in the image
#
# The build is fully offline: all dependencies are in-tree path crates
# (vendor/), so no network or registry access is required.
set -euo pipefail
cd "$(dirname "$0")/.."

# Docs gate (ISSUE 3): every relative markdown link in README.md and
# docs/ must point at a path that exists in the tree. Runs before the
# toolchain check so docs stay honest even on cargo-less machines.
echo "== docs link check (relative markdown links must resolve)"
bad_links=0
for md in README.md docs/*.md; do
  dir=$(dirname "$md")
  links=$(grep -oE '\]\([^)]+\)' "$md" | sed -E 's/^\]\(//; s/\)$//; s/[#?].*$//' || true)
  while IFS= read -r target; do
    [[ -z "$target" ]] && continue
    case "$target" in
      http://*|https://*|mailto:*) continue ;;
    esac
    if [[ ! -e "$dir/$target" && ! -e "$target" ]]; then
      echo "broken link in $md: $target" >&2
      bad_links=1
    fi
  done <<< "$links"
done
[[ "$bad_links" == "0" ]] || exit 1

command -v cargo >/dev/null 2>&1 || {
  echo "error: cargo not found in PATH — install a Rust toolchain to run CI" >&2
  exit 127
}

# Lint allowances, documented per ISSUE 1's CI task. These are style
# lints the seed tree predates; each is allowed (not fixed tree-wide) to
# keep this PR's diff reviewable. Nothing here hides correctness lints.
#   field_reassign_with_default  — the crate's idiom is
#                                  `let mut cfg = X::default(); cfg.f = v;`
#                                  for experiment configs, used throughout.
#   too_many_arguments           — run_policy_over_row mirrors the paper's
#                                  parameter list.
#   inherent_to_string           — util::csv::Csv::to_string predates this
#                                  PR and is part of the public API.
#   new_without_default          — seeded constructors (Rng::new(seed))
#                                  and harness types keep explicit `new`.
#   needless_range_loop          — index loops that touch several parallel
#                                  arrays in the simulator hot path.
CLIPPY_ALLOW=(
  -A clippy::field_reassign_with_default
  -A clippy::too_many_arguments
  -A clippy::inherent_to_string
  -A clippy::new_without_default
  -A clippy::needless_range_loop
)

if [[ "${CI_SKIP_FMT:-0}" != "1" ]]; then
  echo "== cargo fmt --check"
  cargo fmt --check
else
  echo "== cargo fmt skipped (CI_SKIP_FMT=1)"
fi

if [[ "${CI_SKIP_CLIPPY:-0}" != "1" ]]; then
  echo "== cargo clippy (all targets, -D warnings + documented allowances)"
  cargo clippy --all-targets -- -D warnings "${CLIPPY_ALLOW[@]}"
else
  echo "== cargo clippy skipped (CI_SKIP_CLIPPY=1)"
fi

echo "== cargo build --release"
cargo build --release

# Tiered test gate (ISSUE 7): the quick tier is the default `cargo
# test`; POLCA_TEST_FULL=1 widens the randomized populations (500-case
# TOML round-trips, the full SKU x cluster-mix cross-validation grid).
# Both tiers run here, each with its wall-clock recorded, so a drift in
# either tier's cost is visible in the CI log.
echo "== cargo test -q (quick tier)"
tier_start=$SECONDS
cargo test -q
echo "   quick tier: $((SECONDS - tier_start))s"
echo "== POLCA_TEST_FULL=1 cargo test -q (full tier)"
tier_start=$SECONDS
POLCA_TEST_FULL=1 cargo test -q
echo "   full tier: $((SECONDS - tier_start))s"

# Doctest gate (ISSUE 3): the key public entry points (PolicyEngine,
# OobChannel, TelemetryBuffer, fleet::planner, FaultPlan) carry
# runnable rustdoc examples — keep them compiling and passing.
echo "== cargo test --doc"
cargo test --doc -q

# Fault-injection smoke (ISSUE 3): the quick-depth scenario × policy
# grid must run end to end and certify its own invariants (the notes
# it prints include the no-fault-column and containability verdicts).
echo "== fault-matrix smoke (quick depth)"
smoke_out=$(mktemp -d)
./target/release/polca figure fault-matrix --out-dir "$smoke_out" | tail -n 5
rm -rf "$smoke_out"

# Scenario gate (ISSUE 4): every built-in preset must validate and
# round-trip through TOML, and every shipped example scenario file must
# load and validate — adding a preset or example that cannot run is a
# CI failure, not a latent doc bug.
echo "== scenario validate (presets)"
./target/release/polca scenario validate --all
echo "== scenario validate (examples/scenarios/)"
for f in examples/scenarios/*.toml; do
  ./target/release/polca scenario validate "$f"
done
echo "== scenario smoke: polca run oversubscribed-row --quick --weeks 0.02"
./target/release/polca run oversubscribed-row --quick --weeks 0.02 | tail -n 3

# Executor gate (ISSUE 5): the parallel scenario executor must be
# bit-identical to the serial reference path on a user-facing surface —
# run the quick fault matrix both ways and diff the rendered output.
echo "== executor determinism smoke (faults matrix --quick, serial vs parallel)"
par_out=$(mktemp)
ser_out=$(mktemp)
./target/release/polca faults matrix --quick >"$par_out" 2>/dev/null
./target/release/polca faults matrix --quick --serial >"$ser_out" 2>/dev/null
diff "$par_out" "$ser_out" || {
  echo "parallel and serial fault-matrix outputs differ" >&2
  exit 1
}
rm -f "$par_out" "$ser_out"

# Adaptive-controller gate (ISSUE 8): the retune decision sequence
# must be deterministic through the CLI — run the adaptive preset twice
# (second pass with --serial; a row run is a single simulation, so the
# flag is a no-op and both invocations must land on the same answer)
# and diff the JSON reports, which carry the full adapt summary
# (evals/applies/vetoes, final knobs, the decision log). Wall-clock
# never enters the JSON surface, so any diff is a real break. The
# serial-vs-parallel retune property over genuine run_batch fan-out is
# pinned in rust/tests/integration_adapt.rs.
echo "== adaptive retune determinism smoke (polca run adaptive-row --quick, twice)"
ad_a=$(mktemp)
ad_b=$(mktemp)
./target/release/polca run adaptive-row --quick --weeks 0.05 --json >"$ad_a" 2>/dev/null
./target/release/polca run adaptive-row --quick --weeks 0.05 --serial --json >"$ad_b" 2>/dev/null
diff "$ad_a" "$ad_b" || {
  echo "adaptive-row runs diverged (retune-sequence nondeterminism)" >&2
  exit 1
}
grep -q '"adapt"' "$ad_a" || {
  echo "adaptive-row JSON carries no adapt block" >&2
  exit 1
}
rm -f "$ad_a" "$ad_b"

# JSON surface (ISSUE 5): machine-readable output must stay parseable.
echo "== json smoke (polca faults matrix --quick --json | python parse)"
if command -v python3 >/dev/null 2>&1; then
  ./target/release/polca faults matrix --quick --json 2>/dev/null \
    | python3 -c 'import json,sys; d=json.load(sys.stdin); assert d["clean_match"] is True, d'
else
  echo "   (python3 not found — parse check skipped)"
fi

# Trace gate (ISSUE 6): a traced run must emit a parseable JSONL stream
# whose every line matches the record schema, and the trace CLI must be
# able to summarize, render per-incident timelines, and convert to a
# non-empty Chrome trace. (`tail`, never `head`, after polca commands:
# under pipefail a closed pipe would turn a passing gate into exit 141.)
echo "== trace gate (polca run --trace + schema check + trace CLI)"
trace_dir=$(mktemp -d)
./target/release/polca run inference-row --quick --weeks 0.02 \
  --trace "$trace_dir/t.jsonl" | tail -n 3
if command -v python3 >/dev/null 2>&1; then
  python3 - "$trace_dir/t.jsonl" <<'PY'
import json, sys
kinds = {"meta", "counter", "span", "sample", "event"}
counts = {}
with open(sys.argv[1]) as f:
    for i, line in enumerate(f, 1):
        rec = json.loads(line)
        t = rec.get("type")
        assert t in kinds, f"line {i}: unknown record type {t!r}"
        if t in ("sample", "event"):
            ts = rec.get("t_s")
            assert isinstance(ts, (int, float)), f"line {i}: non-numeric t_s {ts!r}"
        counts[t] = counts.get(t, 0) + 1
assert counts.get("meta") == 1, f"expected exactly one meta record: {counts}"
assert counts.get("event", 0) > 0, f"no events recorded: {counts}"
assert counts.get("sample", 0) > 0, f"no series samples recorded: {counts}"
print(f"   trace schema OK: {counts}")
PY
else
  echo "   (python3 not found — schema check skipped)"
fi
./target/release/polca trace summarize "$trace_dir/t.jsonl" | tail -n 3
./target/release/polca run cascade-faults --quick --weeks 0.03 \
  --trace "$trace_dir/c.jsonl" | tail -n 3
./target/release/polca trace timeline "$trace_dir/c.jsonl" | tail -n 12
./target/release/polca trace export "$trace_dir/c.jsonl" \
  --format chrome --out "$trace_dir/c.trace.json"
if command -v python3 >/dev/null 2>&1; then
  python3 -c 'import json,sys; d=json.load(open(sys.argv[1])); assert d["traceEvents"], "empty traceEvents"' \
    "$trace_dir/c.trace.json"
fi
rm -rf "$trace_dir"

# Region gate (ISSUE 7): the compositional trace algebra must stay
# within tolerance of full simulation — `fleet region validate` plans a
# demo region analytically, re-simulates sampled sites end to end, and
# exits nonzero if the worst mean error exceeds 1% or the worst peak
# error exceeds 3%.
echo "== region cross-validation (polca fleet region validate --quick)"
./target/release/polca fleet region validate --quick | tail -n 6

# Gateway gate (ISSUE 9): black-box smoke of the control-plane daemon —
# boot the real binary in the background, poll /healthz until live,
# submit the quick example scenario files over real HTTP, await their
# reports, check /metrics, stop it through POST /shutdown, and require
# a clean exit. Then the report contract, literally: the body served by
# GET /runs/:id must be byte-identical to `polca run <same file> --json`
# stdout (both are the one ScenarioReport::to_json serialization).
echo "== gateway smoke (boot, submit over HTTP, report diff vs --json, shutdown)"
if command -v python3 >/dev/null 2>&1; then
  gw_dir=$(mktemp -d)
  gw_port=$((20000 + RANDOM % 20000))
  ./target/release/polca gateway --addr "127.0.0.1:$gw_port" >"$gw_dir/gw.log" 2>&1 &
  gw_pid=$!
  python3 - "$gw_port" "$gw_dir" <<'PY' || {
import json, sys, time, urllib.request

port, out = sys.argv[1], sys.argv[2]
base = f"http://127.0.0.1:{port}"

def get(path):
    with urllib.request.urlopen(base + path, timeout=10) as r:
        return r.read().decode()

def post(path, body=b""):
    req = urllib.request.Request(base + path, data=body, method="POST")
    with urllib.request.urlopen(req, timeout=10) as r:
        return r.status, r.read().decode()

for _ in range(200):  # poll /healthz until the daemon is live
    try:
        assert json.loads(get("/healthz"))["status"] == "ok"
        break
    except OSError:
        time.sleep(0.05)
else:
    sys.exit("gateway never became healthy")

files = [
    "examples/scenarios/oversubscribed-quick.toml",
    "examples/scenarios/custom-fault-timeline.toml",
]
ids = []
for f in files:
    status, text = post("/scenarios", open(f, "rb").read())
    assert status == 202, (status, text)
    ids.append(json.loads(text)["id"])

deadline = time.time() + 300
for i, rid in enumerate(ids):
    while True:
        text = get(f"/runs/{rid}")
        if '"outcome"' in text:
            break
        assert json.loads(text)["status"] in ("queued", "running"), text
        assert time.time() < deadline, f"{rid} never finished"
        time.sleep(0.1)
    if i == 0:
        open(f"{out}/report.json", "w").write(text)

m = get("/metrics")
assert f"polca_runs_done_total {len(ids)}" in m, m
assert "polca_runs_failed_total 0" in m, m
status, text = post("/shutdown")
assert json.loads(text)["status"] == "shutting-down", text
print(f"   gateway smoke OK: {len(ids)} runs done, metrics live")
PY
    cat "$gw_dir/gw.log" >&2
    kill "$gw_pid" 2>/dev/null || true
    exit 1
  }
  wait "$gw_pid" || { echo "gateway did not exit cleanly after /shutdown" >&2; exit 1; }
  ./target/release/polca run examples/scenarios/oversubscribed-quick.toml --json \
    >"$gw_dir/direct.json" 2>/dev/null
  diff "$gw_dir/report.json" "$gw_dir/direct.json" || {
    echo "gateway report differs from polca run --json output" >&2
    exit 1
  }
  rm -rf "$gw_dir"
else
  echo "   (python3 not found — gateway smoke skipped)"
fi

# Gateway bench smoke (ISSUE 9): the built-in load generator must drive
# an embedded daemon to completion (zero dropped runs — it exits nonzero
# otherwise) and record throughput/latency to BENCH_gateway.json.
echo "== gateway bench smoke (polca gateway bench --quick writes BENCH_gateway.json)"
rm -f BENCH_gateway.json
./target/release/polca gateway bench --quick | tail -n 6
test -f BENCH_gateway.json || { echo "BENCH_gateway.json was not written" >&2; exit 1; }

# Bench smoke (ISSUE 5): record the sweep serial-vs-parallel trajectory
# to BENCH_sim.json on every CI run. Remove any stale file first so the
# existence check below proves THIS run wrote it.
#
# Perf-regression gate (ISSUE 10): the committed BENCH_sim.json is the
# recorded baseline; compare the fresh run's headline sim_events_per_s
# (polca policy) against it and fail only on a >30% regression. Smoke
# numbers are noisy — the 0.70 floor is deliberately loose so only a
# real hot-path regression (not scheduler jitter) trips it. When there
# is no committed baseline or no python3, skip VISIBLY: the first run
# on a toolchain machine records the baseline to commit.
echo "== bench smoke (bench_sim --smoke writes BENCH_sim.json) + perf gate"
baseline_events=""
if command -v python3 >/dev/null 2>&1 && [[ -f BENCH_sim.json ]]; then
  baseline_events=$(python3 -c \
    'import json; print(json.load(open("BENCH_sim.json"))["sim_events_per_s"]["polca"])' \
    2>/dev/null || true)
fi
rm -f BENCH_sim.json
cargo bench --bench bench_sim -- --smoke | tail -n 4
test -f BENCH_sim.json || { echo "BENCH_sim.json was not written" >&2; exit 1; }
if [[ -n "$baseline_events" ]] && command -v python3 >/dev/null 2>&1; then
  python3 - "$baseline_events" <<'PY'
import json, sys
baseline = float(sys.argv[1])
now = float(json.load(open("BENCH_sim.json"))["sim_events_per_s"]["polca"])
ratio = now / baseline
print(f"   perf gate: sim_events_per_s {now:.0f} vs baseline {baseline:.0f} ({ratio:.2f}x)")
if ratio < 0.70:
    sys.exit(f"perf regression: sim_events_per_s fell to {ratio:.2f}x of the "
             "committed baseline (floor 0.70x)")
PY
else
  echo "   perf gate skipped: no committed BENCH_sim.json baseline (or no python3)" \
       "— this run's BENCH_sim.json is the baseline to commit"
fi

# Docs gate (ISSUE 2): the crate carries #![warn(missing_docs)] and the
# ARCHITECTURE/README docs reference rustdoc items — keep both honest by
# denying all rustdoc warnings (missing docs, broken intra-doc links).
# --lib avoids the doc-output filename collision with the same-named bin.
echo "== cargo doc --no-deps (warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --lib --quiet

echo "CI OK"
